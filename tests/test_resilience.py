"""Fault tolerance: non-finite quarantine in the streaming scans, posterior
checkpoint/restore (bit-identical resume), bounded-queue shedding, request
timeouts, worker supervision, compile retry, swap abort — driven by the
seeded injectors in ``repro.resilience.faultinject``."""

import contextlib
import os
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import streaming, vmp
from repro.core.dag import PlateSpec
from repro.data import synthetic as syn
from repro.data.stream import Attribute, DataStream, REAL, FINITE
from repro.obs import sink as obs
from repro.resilience import (CheckpointManager, DeadlineError, FaultInjector,
                              ShedError, TransientCompileError,
                              checkpointed_stream_fit, resume_stream_fit)
from repro.resilience import checkpoint as ckpt
from repro.serve.plan import PlanCache, PlanKey
from repro.serve.queue import AsyncPGMServer, SwapHandle


@contextlib.contextmanager
def _obs_to(tmp_path, level="basic"):
    path = str(tmp_path / "events.jsonl")
    prev = obs.configure(level=level, path=path, reset_counters=True)
    try:
        yield path
    finally:
        obs.configure(level=prev["level"], path=prev["path"],
                      reset_counters=True)


def _plate_setup(n_batches=8, batch=120, f=3, seed=0):
    stream, _, _ = syn.gmm_stream(n_batches * batch, 2, f, seed=seed)
    spec = PlateSpec(n_features=f, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(0))
    batches = list(stream.batches(batch))
    xcs = jnp.stack([b.xc for b in batches])
    xds = jnp.stack([b.xd for b in batches])
    return cp, prior, init, xcs, xds


def _tree_equal(a, b):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(la, lb))


# ---------------------------------------------------------------------------
# non-finite quarantine (core/streaming scan body)
# ---------------------------------------------------------------------------


def test_quarantine_skips_poisoned_batches_bit_identical():
    """A poisoned batch is SKIPPED: the final posterior equals, bit for
    bit, a run that never saw those batches at all (held state + held
    Page-Hinkley drift detector)."""
    cp, prior, init, xcs, xds = _plate_setup()
    inj = FaultInjector(seed=3)
    bad, idx = inj.poison_nan(np.asarray(xcs), rate=0.25)
    assert 0 < len(idx) < xcs.shape[0]

    sp, info_p = streaming.stream_fit(cp, prior,
                                      streaming.stream_init(prior, init),
                                      jnp.asarray(bad), xds)
    keep = np.setdiff1d(np.arange(xcs.shape[0]), idx)
    sc, _ = streaming.stream_fit(cp, prior,
                                 streaming.stream_init(prior, init),
                                 xcs[keep], xds[keep])

    q = np.asarray(info_p["quarantined"]).astype(bool)
    assert list(np.nonzero(q)[0]) == list(idx)
    assert int(sp.n_quarantined) == len(idx)
    assert float(sp.n_seen) == float(sc.n_seen)
    assert _tree_equal(sp.post, sc.post)
    assert _tree_equal(sp.prior, sc.prior)
    assert _tree_equal(sp.drift, sc.drift)
    # sanitized telemetry: no NaN leaks into the info columns
    for k in ("elbo", "score", "ph"):
        assert np.isfinite(np.asarray(info_p[k])).all()


def test_quarantine_update_loop_matches_scan():
    """The eager per-batch driver shares the step body, so it quarantines
    identically to the fused scan."""
    cp, prior, init, xcs, xds = _plate_setup(n_batches=5)
    bad, idx = FaultInjector(seed=1).poison_nan(np.asarray(xcs), rate=0.2)

    ss = streaming.stream_init(prior, init)
    flags = []
    for t in range(bad.shape[0]):
        ss, info = streaming.stream_update(cp, prior, ss,
                                           jnp.asarray(bad[t]), xds[t])
        flags.append(bool(info["quarantined"]))
    sf, infos = streaming.stream_fit(cp, prior,
                                     streaming.stream_init(prior, init),
                                     jnp.asarray(bad), xds)
    assert flags == [bool(x) for x in np.asarray(infos["quarantined"])]
    assert int(ss.n_quarantined) == int(sf.n_quarantined) == len(idx)
    assert _tree_equal(ss.post, sf.post)


def test_quarantine_events_emitted(tmp_path):
    cp, prior, init, xcs, xds = _plate_setup(n_batches=5)
    bad, idx = FaultInjector(seed=2).poison_nan(np.asarray(xcs), rate=0.2)
    with _obs_to(tmp_path) as path:
        streaming.stream_fit(cp, prior, streaming.stream_init(prior, init),
                             jnp.asarray(bad), xds)
        counts = obs.validate_obs_events(path)
    assert counts.get("quarantine", 0) == len(idx)
    assert counts.get("stream_batch", 0) == bad.shape[0]


def test_seq_stream_fit_quarantines_poisoned_sequence_batch():
    """Temporal analog: a NaN sequence batch holds the chained HMM
    posterior exactly — the final model matches a run without it."""
    from repro.pgm_models import HiddenMarkovModel, seq_stream_fit

    batches, attrs, _ = syn.hmm_stream(n_batches=5, s=12, t=10, states=2,
                                       f=2, shift=0.0, seed=4)
    poisoned = batches[:]
    poisoned[2] = syn.DynamicDataStream(
        attrs, np.full_like(poisoned[2].xc, np.nan))

    mp = HiddenMarkovModel(attrs, n_states=2, seed=0)
    info = seq_stream_fit(mp, poisoned, sweeps=4, tol=0.0)
    mc = HiddenMarkovModel(attrs, n_states=2, seed=0)
    seq_stream_fit(mc, batches[:2] + batches[3:], sweeps=4, tol=0.0)

    q = np.asarray(info["quarantined"]).astype(bool)
    assert list(np.nonzero(q)[0]) == [2]
    assert mp.n_quarantined == 1
    assert _tree_equal(mp.posterior, mc.posterior)


# ---------------------------------------------------------------------------
# posterior checkpoint/restore
# ---------------------------------------------------------------------------


def test_checkpoint_roundtrip_with_meta(tmp_path):
    cp, prior, init, xcs, xds = _plate_setup(n_batches=3)
    state, _ = streaming.stream_fit(cp, prior,
                                    streaming.stream_init(prior, init),
                                    xcs, xds)
    path = str(tmp_path / "s.npz")
    ckpt.save(path, state, {"t": 3, "network_version": 7})
    like = streaming.stream_init(prior, init)
    restored, meta = ckpt.load(path, like)
    assert meta["t"] == 3 and meta["network_version"] == 7
    assert _tree_equal(state, restored)


def test_checkpoint_manager_retention_and_policy(tmp_path):
    mgr = CheckpointManager(str(tmp_path), every=2, keep=2, on_drift=True)
    state = {"w": np.arange(4.0)}
    assert mgr.maybe_save(0, state) is not None       # first always fires
    assert mgr.maybe_save(1, state) is None           # within the period
    assert mgr.maybe_save(2, state) is not None
    assert mgr.maybe_save(3, state, drifted=True) is not None   # on-drift
    paths = mgr.paths()
    assert len(paths) == 2                            # pruned to keep=2
    assert mgr.latest() == paths[-1] == mgr.path_for(3)
    _, meta = ckpt.load(mgr.latest(), state)
    assert meta["reason"] == "drift"


def test_resume_mid_stream_bit_identical(tmp_path):
    """Crash recovery: checkpoint at batch k, resume from disk over the
    tail — the final state must equal the uninterrupted run EXACTLY."""
    cp, prior, init, xcs, xds = _plate_setup()
    k = 3
    mgr = CheckpointManager(str(tmp_path), every=0, keep=3)

    head, _ = streaming.stream_fit(cp, prior,
                                   streaming.stream_init(prior, init),
                                   xcs[:k], xds[:k])
    mgr.save(k, head)
    # "crash" — a fresh process restores from disk and continues
    resumed, info_tail = resume_stream_fit(
        cp, prior, streaming.stream_init(prior, init), xcs, xds, manager=mgr)
    full, info_full = streaming.stream_fit(
        cp, prior, streaming.stream_init(prior, init), xcs, xds)

    assert info_tail["elbo"].shape[0] == xcs.shape[0] - k
    assert _tree_equal(resumed, full)
    np.testing.assert_array_equal(np.asarray(info_tail["elbo"]),
                                  np.asarray(info_full["elbo"][k:]))


def test_checkpointed_stream_fit_segments_and_events(tmp_path):
    cp, prior, init, xcs, xds = _plate_setup(n_batches=6)
    mgr = CheckpointManager(str(tmp_path / "ck"), every=2, keep=10)
    with _obs_to(tmp_path) as path:
        state, info = checkpointed_stream_fit(
            cp, prior, streaming.stream_init(prior, init), xcs, xds,
            manager=mgr)
        counts = obs.validate_obs_events(path)
    assert info["elbo"].shape[0] == 6
    assert len(mgr.paths()) == 3                      # t = 2, 4, 6
    assert counts.get("checkpoint", 0) == 3
    full, _ = streaming.stream_fit(cp, prior,
                                   streaming.stream_init(prior, init),
                                   xcs, xds)
    assert _tree_equal(state, full)                   # segmenting is exact


# ---------------------------------------------------------------------------
# serving robustness
# ---------------------------------------------------------------------------


def _discrete_bn(seed=0):
    return syn.random_discrete_bn(5, card=2, max_parents=2, seed=seed)


def _q(bn, i=0):
    names = [v.name for v in bn.order]
    return names[-1], {names[0]: float(i % 2)}


def test_submit_sheds_over_max_queue():
    bn = _discrete_bn()
    with AsyncPGMServer(bn, mode="exact", max_batch=64, max_delay_ms=10_000,
                        default_deadline_ms=60_000, max_queue=2) as srv:
        kept = [srv.submit(*_q(bn)) for _ in range(2)]
        shed = [srv.submit(*_q(bn)) for _ in range(3)]
        for t in shed:
            assert t.done() and t.trigger == "shed"
            with pytest.raises(ShedError):
                t.result()
        st = srv.stats()
        assert st["shed"] == 3 and st["submitted"] == 2
    for t in kept:                                    # drained on stop
        assert t.error is None and t.result() is not None
    assert srv.stats()["pending"] == 0


def test_request_timeout_fails_stuck_flush_with_deadline_error():
    bn = _discrete_bn()
    inj = FaultInjector()
    with AsyncPGMServer(bn, mode="exact", max_batch=1, max_delay_ms=1,
                        default_deadline_ms=40, request_timeout_ms=40,
                        supervise_interval_ms=5) as srv:
        srv.submit(*_q(bn), deadline_ms=60_000).result(timeout=120)  # warm
        inj.slow_flush(srv, delay_s=1.5, n=1)
        t = srv.submit(*_q(bn))
        with pytest.raises(DeadlineError):
            t.result(timeout=120)
        assert t.deadline_miss and t.trigger == "watchdog"
        # the server recovers once the stall clears
        ok = srv.submit(*_q(bn, 1), deadline_ms=60_000)
        assert ok.result(timeout=120) is not None
    assert srv.stats()["pending"] == 0


def test_worker_crash_requeues_bucket_and_respawns_zero_loss(tmp_path):
    bn = _discrete_bn()
    inj = FaultInjector()
    with _obs_to(tmp_path) as path:
        with AsyncPGMServer(bn, mode="exact", max_batch=4,
                            max_delay_ms=10_000, default_deadline_ms=60_000,
                            supervise_interval_ms=5) as srv:
            inj.crash_worker(srv, widx=0)
            tickets = [srv.submit(*_q(bn)) for _ in range(4)]  # size trigger
            results = [t.result(timeout=120) for t in tickets]
            st = srv.stats()
            assert st["worker_restarts"] >= 1
            assert st["pending"] == 0                 # zero lost tickets
        counts = obs.validate_obs_events(path)
    assert counts.get("serve_worker", 0) >= 1
    assert all(t.error is None for t in tickets)
    assert all(np.isfinite(r).all() for r in results)


def test_plan_cache_compile_retry_after_transient_failure(tmp_path):
    cache = PlanCache(compile_retries=2, retry_backoff_s=0.01)
    FaultInjector().fail_compiles(cache, n=2)
    key = PlanKey(0, "jt-discrete", ("D0",), (4,), ("float32",))
    with _obs_to(tmp_path) as path:
        plan = cache.get(key, lambda: (lambda x: x + 1))
        counts = obs.validate_obs_events(path)
    assert plan.run(1) == 2
    assert cache.retries == 2
    assert counts.get("serve_retry", 0) == 2


def test_plan_cache_build_raise_leaves_no_poisoned_entry():
    """Satellite: an exhausted build failure inserts nothing — the next
    get() with a working build compiles cleanly."""
    cache = PlanCache()                               # no retry budget
    key = PlanKey(0, "jt-discrete", ("D0",), (4,), ("float32",))

    def bad():
        raise TransientCompileError("boom")

    with pytest.raises(TransientCompileError):
        cache.get(key, bad)
    assert key not in cache and len(cache) == 0
    plan = cache.get(key, lambda: (lambda x: x * 2))
    assert plan.run(3) == 6
    assert cache.stats()["misses"] == 2


def test_swap_model_nonblocking_returns_handle():
    bn, bn2 = _discrete_bn(0), _discrete_bn(9)
    with AsyncPGMServer(bn, mode="exact", max_batch=8, max_delay_ms=5,
                        default_deadline_ms=60_000) as srv:
        srv.submit(*_q(bn)).result(timeout=120)       # warm a v0 plan
        handle = srv.swap_model(bn2, block=False)
        assert isinstance(handle, SwapHandle)
        info = handle.wait(timeout=120)
        assert handle.done() and info["new_version"] == 1
        assert srv.stats()["network_version"] == 1
        # serving continues on the new version
        t = srv.submit(*_q(bn))
        assert t.result(timeout=120) is not None
    assert all(k.network_version == 1 for k in srv.plans.keys())


def test_swap_abort_on_warm_compile_failure_keeps_old_engines(tmp_path):
    """Satellite: a compile failure mid-warm aborts the swap — the old
    engines serve on untouched and no new-version plans linger."""
    bn, bn2 = _discrete_bn(0), _discrete_bn(9)
    cache = PlanCache()                               # no retry budget
    with AsyncPGMServer(bn, mode="exact", max_batch=8, max_delay_ms=5,
                        default_deadline_ms=60_000, plan_cache=cache) as srv:
        before = srv.submit(*_q(bn)).result(timeout=120)
        FaultInjector().fail_compiles(cache, n=10)
        with pytest.raises(TransientCompileError):
            srv.swap_model(bn2)
        FaultInjector.disarm(cache=cache)
        assert srv.stats()["network_version"] == 0
        assert all(k.network_version == 0 for k in cache.keys())
        after = srv.submit(*_q(bn)).result(timeout=120)
        assert np.array_equal(before, after)          # old model still serves


def test_chaos_combined_nan_crash_compile_failure_zero_loss():
    """The acceptance chaos run: 1%-NaN-poisoned training stream, one
    worker crash and one transient compile failure in a single serving
    run — the learner survives and the server loses zero accepted
    tickets."""
    from repro.pgm_models import GaussianMixture

    clean, _, _ = syn.gmm_stream(2000, 3, 4, seed=5)
    poisoned = syn.poison_stream(clean, rate=0.01, seed=6)
    guarded = DataStream(poisoned.attributes, poisoned.chunks,
                         n_instances=poisoned.n_instances, validate=True)
    m = GaussianMixture(guarded.attributes, n_states=3)
    m.update_model(guarded)
    assert guarded.quarantined > 0                    # corruption was real
    xs = np.asarray(clean.collect().xc)

    cache = PlanCache(compile_retries=2, retry_backoff_s=0.01)
    inj = FaultInjector(seed=7)
    with AsyncPGMServer(m, mode="vmp", max_batch=4, max_delay_ms=20,
                        default_deadline_ms=60_000, replicas=2,
                        plan_cache=cache, supervise_interval_ms=5) as srv:
        # warm one bucket, then inject: crash + transient compile failure
        srv.submit("Z", {f"X{i}": float(xs[0, i]) for i in range(4)}
                   ).result(timeout=120)
        crash = inj.crash_worker(srv)                 # any worker
        inj.fail_compiles(cache, n=1)                 # within retry budget
        tickets = []
        for j in range(1, 25):
            ev = {f"X{i}": float(xs[j, i]) for i in range(4)}
            tickets.append(srv.submit("Z", ev))
        results = [t.result(timeout=120) for t in tickets]
        assert crash["fired"]       # the awaited results crossed the crash
        st = srv.stats()
        assert st["worker_restarts"] >= 1
        assert st["plans"]["retries"] >= 1
        assert st["pending"] == 0                     # zero lost tickets
    assert all(t.error is None for t in tickets)
    assert all(np.isfinite(r).all() for r in results)


# ---------------------------------------------------------------------------
# data-layer validation / poisoning satellites
# ---------------------------------------------------------------------------


def test_datastream_validate_quarantines_bad_rows():
    attrs = [Attribute("X0", REAL), Attribute("X1", REAL),
             Attribute("D0", FINITE, 2)]
    xc = np.zeros((6, 2), np.float32)
    xc[1, 0] = np.nan
    xc[4, 1] = np.inf
    xd = np.zeros((6, 1), np.int32)
    xd[2, 0] = 5                                      # out of range (card 2)

    def src():
        yield xc[:3], xd[:3]
        yield xc[3:], xd[3:]

    ds = DataStream(attrs, src, n_instances=6, validate=True)
    got = ds.collect()
    assert got.xc.shape[0] == 3                       # rows 1, 2, 4 dropped
    assert ds.quarantined == 3
    assert ds.chunk_quarantine == [2, 1]
    assert np.isfinite(np.asarray(got.xc)).all()

    # schema violations are programming errors, not data faults
    bad = DataStream(attrs, lambda: iter([(xc[:, :1], xd)]), validate=True)
    with pytest.raises(ValueError, match="does not match schema"):
        list(bad.chunks())


def test_poison_stream_is_seeded_and_validate_recovers():
    stream, _, _ = syn.gmm_stream(500, 2, 3, seed=0)
    a = syn.poison_stream(stream, rate=0.1, seed=42).collect()
    b = syn.poison_stream(stream, rate=0.1, seed=42).collect()
    np.testing.assert_array_equal(np.asarray(a.xc), np.asarray(b.xc))
    n_bad = int(np.isnan(np.asarray(a.xc)).any(axis=1).sum())
    assert 0 < n_bad < 500

    poisoned = syn.poison_stream(stream, rate=0.1, seed=42)
    guarded = DataStream(poisoned.attributes, poisoned.chunks,
                         n_instances=poisoned.n_instances, validate=True)
    clean = guarded.collect()
    assert guarded.quarantined == n_bad
    assert clean.xc.shape[0] == 500 - n_bad
    assert np.isfinite(np.asarray(clean.xc)).all()
