"""VMP engine: recovery, ELBO monotonicity, inference."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import vmp
from repro.core.dag import PlateSpec


@pytest.fixture(scope="module")
def gmm_data():
    key = jax.random.PRNGKey(0)
    k1, k2, k3 = jax.random.split(key, 3)
    N = 1500
    z = jax.random.bernoulli(k1, 0.4, (N,)).astype(int)
    mus = jnp.array([[3.0, -2.0, 0.0], [-3.0, 2.0, 5.0]])
    x = mus[z] + 0.7 * jax.random.normal(k2, (N, 3))
    return x, z, mus, k3


def test_gmm_recovery(gmm_data):
    x, z, mus, key = gmm_data
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, key)
    xd = jnp.zeros((x.shape[0], 0), jnp.int32)
    st = vmp.vmp_fit(cp, prior, init, x, xd, 100, 1e-6)
    learnt = np.sort(np.asarray(st.post.reg.m[:, :, 0]).T, axis=0)
    np.testing.assert_allclose(learnt, np.sort(np.asarray(mus), 0), atol=0.15)
    # perfect clustering up to label swap
    r = vmp.posterior_z(cp, st.post, x, xd)
    acc = max(float((r.argmax(1) == z).mean()),
              float((r.argmax(1) != z).mean()))
    assert acc > 0.98


def test_elbo_increases_over_sweeps(gmm_data):
    x, _, _, key = gmm_data
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    post = vmp.symmetry_broken(prior, key)
    xd = jnp.zeros((x.shape[0], 0), jnp.int32)
    mask = jnp.ones(x.shape[0])
    elbos = []
    for _ in range(8):
        stats, _ = vmp.local_step(cp, post, x, xd, mask)
        post = vmp.global_update(prior, stats)
        elbos.append(float(vmp.elbo(cp, prior, post, stats)))
    diffs = np.diff(elbos)
    assert (diffs > -1e-3 * np.abs(np.asarray(elbos[1:]))).all(), elbos


def test_supervised_r_fixed(gmm_data):
    """Clamping q(Z) to the labels gives class-conditional estimates."""
    x, z, mus, key = gmm_data
    spec = PlateSpec(n_features=3, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    xd = jnp.zeros((x.shape[0], 0), jnp.int32)
    r = jax.nn.one_hot(z, 2)
    stats, _ = vmp.local_step(cp, prior, x, xd, jnp.ones(x.shape[0]), r)
    post = vmp.global_update(prior, stats)
    learnt = np.asarray(post.reg.m[:, :, 0]).T   # [K, F]
    np.testing.assert_allclose(learnt, np.asarray(mus), atol=0.15)


def test_latent_dim_fa_structure():
    """PPCA-style plate: latent H explains cross-feature covariance."""
    key = jax.random.PRNGKey(3)
    k1, k2, k3 = jax.random.split(key, 3)
    N, F, L = 1200, 5, 2
    W = jax.random.normal(k1, (F, L))
    h = jax.random.normal(k2, (N, L))
    x = h @ W.T + 0.2 * jax.random.normal(k3, (N, F))
    spec = PlateSpec(n_features=F, latent_card=0, latent_dim=L)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, key)
    st = vmp.vmp_fit(cp, prior, init, x, jnp.zeros((N, 0), jnp.int32),
                     120, 1e-6)
    lay = cp.layout
    loadings = np.asarray(st.post.reg.m[:, 0, 1 + lay.P:])   # [F, L]
    u1, _, _ = np.linalg.svd(np.asarray(W), full_matrices=False)
    u2, _, _ = np.linalg.svd(loadings, full_matrices=False)
    # principal angle overlap of the column spaces
    s = np.linalg.svd(u1.T @ u2)[1]
    assert s.min() > 0.9, s


def test_mixed_discrete_continuous():
    from repro.data.synthetic import nb_stream

    stream, y = nb_stream(1200, 3, 2, 2, seed=4)
    batch = stream.collect()   # xd: 2 discrete features + the class column
    spec = PlateSpec(n_features=5, latent_card=3,
                     discrete_features=((2, 3), (3, 3), (4, 3)))
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(1))
    st = vmp.vmp_fit(cp, prior, init, batch.xc, batch.xd, 80, 1e-6)
    assert np.isfinite(float(st.elbo))
