"""ARFF IO round-trip, Trainer loop, PGM workload configs."""

import numpy as np
import pytest

from repro.data import synthetic as syn
from repro.data.io import load_arff, load_dynamic_arff, save_arff


def test_arff_roundtrip(tmp_path):
    stream, y = syn.nb_stream(50, 3, 2, 2, seed=0)
    path = str(tmp_path / "d.arff")
    save_arff(path, stream)
    loaded = load_arff(path)
    a = stream.collect()
    b = loaded.collect()
    np.testing.assert_allclose(np.asarray(a.xc), np.asarray(b.xc), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(a.xd), np.asarray(b.xd))
    assert [x.name for x in loaded.attributes] == \
        [x.name for x in stream.attributes]


def test_dynamic_arff(tmp_path):
    # build a small dynamic ARFF by hand (paper Code Fragment 4 layout)
    path = str(tmp_path / "dyn.arff")
    with open(path, "w") as f:
        f.write("@relation dyn\n")
        f.write("@attribute SEQUENCE_ID REAL\n@attribute TIME_ID REAL\n")
        f.write("@attribute G0 REAL\n@data\n")
        for s in range(2):
            for t in range(3):
                f.write(f"{s},{t},{s * 10 + t}\n")
    ds = load_dynamic_arff(path)
    batch = ds.collect()
    assert batch.xc.shape == (2, 3, 1)
    assert float(batch.xc[1, 2, 0]) == 12.0
    assert float(batch.mask.sum()) == 6.0


def test_trainer_loop_and_drift_response():
    from repro.configs import get_config
    from repro.data.tokens import TokenStream, drift_corpus
    from repro.nn import transformer as T
    from repro.train.trainer import Trainer, TrainerConfig
    import jax

    cfg = get_config("granite-3-2b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    corpus = drift_corpus(15_000, cfg.vocab, seed=1)

    def batches():
        for i in range(40):
            half = 0 if i < 25 else 15_000
            s = TokenStream(corpus[half:half + 15_000], 8, 64, seed=i)
            yield next(iter(s.batches(1)))

    tr = Trainer(cfg, params, TrainerConfig(
        optimizer="vb", lr=0.05, steps=40, n_total=2e4,
        drift_threshold=1.0, log_every=0, eval_every=0))
    out = tr.fit(batches())
    assert out["steps"] == 40
    assert np.isfinite(out["final_loss"])
    # the corpus switch at step 25 must leave a visible loss bump even if
    # the PH statistic stays under threshold (VB adapts fast)
    h = np.asarray(tr.history)
    assert out["n_drifts"] >= 1 or h[25:28].mean() > h[20:25].mean() + 0.05


def test_pgm_workloads_compile():
    from repro.configs.amidst_pgm import PGM_WORKLOADS
    from repro.core import vmp

    for name, wl in PGM_WORKLOADS.items():
        cp = vmp.compile_plate(wl.spec)
        assert cp.layout.F + cp.layout.Fd == wl.spec.n_features
        assert wl.nodes_per_instance() >= wl.spec.n_features
    # the d-VMP scale claim arithmetic
    gmm = PGM_WORKLOADS["gmm_large"]
    assert gmm.nodes_per_instance() * 100_000_000 > 1_000_000_000
