"""Property-based tests (hypothesis) on system invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import expfam as ef
from repro.core import svi, vmp
from repro.core.dag import PlateSpec
from repro.nn import attention as A
from repro.sharding.specs import fix_spec
from jax.sharding import PartitionSpec as P

SETTINGS = dict(max_examples=20, deadline=None)


@settings(**SETTINGS)
@given(st.integers(2, 6), st.integers(10, 60), st.integers(0, 2 ** 31 - 1))
def test_suffstats_shard_additivity(k, n, seed):
    """THE d-VMP invariant: messages are additive over any data split."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(2 * n, 3)).astype(np.float32))
    xd = jnp.zeros((2 * n, 0), jnp.int32)
    spec = PlateSpec(n_features=3, latent_card=k)
    cp = vmp.compile_plate(spec)
    params = vmp.symmetry_broken(vmp.default_prior(cp),
                                 jax.random.PRNGKey(seed % 1000))
    full, _ = vmp.local_step(cp, params, x, xd, jnp.ones(2 * n))
    a, _ = vmp.local_step(cp, params, x[:n], xd[:n], jnp.ones(n))
    b, _ = vmp.local_step(cp, params, x[n:], xd[n:], jnp.ones(n))
    for fa, sa, sb in zip(jax.tree_util.tree_leaves(full),
                          jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(fa), np.asarray(sa + sb),
                                   rtol=2e-3, atol=2e-3)


@settings(**SETTINGS)
@given(st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_dirichlet_update_order_invariance(k, seed):
    rng = np.random.default_rng(seed)
    prior = ef.Dirichlet(jnp.asarray(rng.uniform(0.5, 3.0, k + 1)
                                     .astype(np.float32)))
    c1 = jnp.asarray(rng.uniform(0, 10, k + 1).astype(np.float32))
    c2 = jnp.asarray(rng.uniform(0, 10, k + 1).astype(np.float32))
    a = ef.dirichlet_update(ef.dirichlet_update(prior, c1), c2)
    b = ef.dirichlet_update(ef.dirichlet_update(prior, c2), c1)
    np.testing.assert_allclose(np.asarray(a.alpha), np.asarray(b.alpha),
                               rtol=1e-6)


@settings(**SETTINGS)
@given(st.integers(2, 5), st.integers(0, 2 ** 31 - 1))
def test_natural_roundtrip_property(k, seed):
    spec = PlateSpec(n_features=2, latent_card=k)
    cp = vmp.compile_plate(spec)
    params = vmp.symmetry_broken(vmp.default_prior(cp),
                                 jax.random.PRNGKey(seed % 997))
    back = svi.from_natural(svi.to_natural(params))
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


@settings(**SETTINGS)
@given(st.integers(1, 40), st.integers(4, 16))
def test_ring_buffer_position_reconstruction(length, cap):
    """Every cache slot's reconstructed absolute position is the latest
    write < length congruent to the slot (the ring invariant)."""
    slots = np.arange(cap)
    wraps = (length - 1 - slots) // cap
    abs_pos = slots + wraps * cap
    for s in range(cap):
        cands = [t for t in range(length) if t % cap == s]
        if cands:
            assert abs_pos[s] == max(cands)
        else:
            assert abs_pos[s] < 0 or abs_pos[s] >= length


@settings(**SETTINGS)
@given(st.integers(1, 7), st.integers(1, 5), st.integers(0, 2 ** 31 - 1))
def test_attention_blockwise_equals_reference(sq_blocks, hkv, seed):
    rng = np.random.default_rng(seed)
    S = sq_blocks * 13 + 1
    Hq = hkv * 2
    q = jnp.asarray(rng.normal(size=(1, S, Hq, 8)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(1, S, hkv, 8)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(1, S, hkv, 8)).astype(np.float32))
    r = A.attention_reference(q, k, v, causal=True)
    b = A.attention_blockwise(q, k, v, causal=True, kv_block=16)
    np.testing.assert_allclose(np.asarray(r), np.asarray(b),
                               atol=1e-5, rtol=1e-5)


@settings(**SETTINGS)
@given(st.tuples(st.integers(1, 64), st.integers(1, 64)),
       st.sampled_from([("data", 2), ("model", 4), ("model", 16)]))
def test_fix_spec_always_divides(shape, axis):
    name, size = axis
    spec = P(name, None)
    fixed = fix_spec(spec, shape, {name: size})
    for dim, ax in zip(shape, tuple(fixed) + (None,) * 2):
        if ax is not None:
            assert dim % size == 0


@settings(**SETTINGS)
@given(st.integers(0, 2 ** 31 - 1))
def test_streaming_two_halves_equals_one_batch_supervised(seed):
    """Eq. 3 is EXACT for conjugate (supervised) updates: chaining the
    posterior over two half-batches equals one full-batch update."""
    rng = np.random.default_rng(seed)
    n = 60
    x = jnp.asarray(rng.normal(size=(2 * n, 2)).astype(np.float32))
    z = jnp.asarray(rng.integers(0, 2, 2 * n))
    r = jax.nn.one_hot(z, 2)
    xd = jnp.zeros((2 * n, 0), jnp.int32)
    spec = PlateSpec(n_features=2, latent_card=2)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    # one shot
    s_full, _ = vmp.local_step(cp, prior, x, xd, jnp.ones(2 * n), r)
    post_full = vmp.global_update(prior, s_full)
    # chained
    s1, _ = vmp.local_step(cp, prior, x[:n], xd[:n], jnp.ones(n), r[:n])
    p1 = vmp.global_update(prior, s1)
    s2, _ = vmp.local_step(cp, p1, x[n:], xd[n:], jnp.ones(n), r[n:])
    p2 = vmp.global_update(p1, s2)
    np.testing.assert_allclose(np.asarray(post_full.reg.m),
                               np.asarray(p2.reg.m), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(post_full.mix.alpha),
                               np.asarray(p2.mix.alpha), rtol=1e-5)
