"""Exponential-family algebra: conjugate updates vs closed forms."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import expfam as ef


def test_dirichlet_update_and_mean():
    prior = ef.Dirichlet(jnp.array([1.0, 1.0, 1.0]))
    post = ef.dirichlet_update(prior, jnp.array([10.0, 0.0, 30.0]))
    np.testing.assert_allclose(
        ef.dirichlet_mean(post), [11 / 43, 1 / 43, 31 / 43], rtol=1e-6)


def test_dirichlet_kl_zero_and_positive():
    d = ef.Dirichlet(jnp.array([2.0, 3.0]))
    assert float(ef.dirichlet_kl(d, d)) == pytest.approx(0.0, abs=1e-6)
    e = ef.Dirichlet(jnp.array([1.0, 5.0]))
    assert float(ef.dirichlet_kl(d, e)) > 0


def test_normalgamma_posterior_matches_closed_form():
    rng = np.random.default_rng(0)
    x = rng.normal(2.5, 1.3, size=500).astype(np.float32)
    prior = ef.NormalGamma(jnp.array(0.0), jnp.array(1.0),
                           jnp.array(1.0), jnp.array(1.0))
    stats = ef.gauss_suffstats(jnp.asarray(x), jnp.ones(500))
    post = ef.normalgamma_update(prior, stats)
    # posterior mean of mu
    assert float(post.mu0) == pytest.approx(x.mean(), abs=0.02)
    # posterior mean of variance b/a ~ sample var
    assert float(post.b / post.a) == pytest.approx(x.var(), rel=0.1)


def test_normalgamma_kl_properties():
    q = ef.NormalGamma(jnp.array(1.0), jnp.array(2.0), jnp.array(3.0),
                       jnp.array(2.0))
    assert float(ef.normalgamma_kl(q, q)) == pytest.approx(0.0, abs=1e-5)
    p = ef.NormalGamma(jnp.array(0.0), jnp.array(1.0), jnp.array(1.0),
                       jnp.array(1.0))
    assert float(ef.normalgamma_kl(q, p)) > 0


def test_mvnormalgamma_recovers_regression():
    rng = np.random.default_rng(1)
    N, D = 2000, 3
    w = np.array([0.5, -1.2, 2.0], np.float32)
    X = rng.normal(size=(N, D)).astype(np.float32)
    y = X @ w + 0.3 * rng.normal(size=N).astype(np.float32)
    prior = ef.MVNormalGamma(m=jnp.zeros(D), K=jnp.eye(D),
                             a=jnp.array(1.0), b=jnp.array(1.0))
    stats = ef.reg_suffstats(jnp.asarray(X), jnp.asarray(y), jnp.ones((N,)))
    post = ef.mvnormalgamma_update(prior, stats)
    np.testing.assert_allclose(np.asarray(post.m), w, atol=0.05)
    # noise precision E[lam] = a/b ~ 1/0.09
    assert float(post.a / post.b) == pytest.approx(1 / 0.09, rel=0.15)


def test_suffstat_additivity():
    """The d-VMP property: stats are additive over data shards."""
    rng = np.random.default_rng(2)
    X = jnp.asarray(rng.normal(size=(100, 2)).astype(np.float32))
    y = jnp.asarray(rng.normal(size=100).astype(np.float32))
    w = jnp.ones((100,))
    full = ef.reg_suffstats(X, y, w)
    a = ef.reg_suffstats(X[:40], y[:40], w[:40])
    b = ef.reg_suffstats(X[40:], y[40:], w[40:])
    for fa, (sa, sb) in zip(full, zip(a, b)):
        if fa is None:          # optional lazy sxx_hh: unused here
            assert sa is None and sb is None
            continue
        np.testing.assert_allclose(fa, sa + sb, rtol=1e-5, atol=1e-4)
