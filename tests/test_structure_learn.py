"""Structure learning: family scores, Chow-Liu/TAN, hill-climbing, drift
re-search (repro.learn_structure) — recovery asserted against the
ground-truth generators in data.synthetic."""

import math

import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import synthetic as syn
from repro.data.stream import Attribute, DataStream, FINITE, REAL
from repro.learn_structure import (AdaptiveStructure, chow_liu, fit_cpds,
                                   hill_climb, nig_evidence, predict_class,
                                   tan)
from repro.learn_structure import scores as S
from repro.learn_structure.metrics import skeleton_f1, undirected_edges


# ---------------------------------------------------------------------------
# scores
# ---------------------------------------------------------------------------


def test_bdeu_matches_naive_enumeration():
    """The batched BDeu path (family_counts kernel + vectorized lgamma
    algebra) against a per-cell Python enumeration."""
    rng = np.random.default_rng(1)
    N, cards, ess = 300, [2, 3, 2], 1.0
    xd = jnp.asarray(np.stack([rng.integers(0, c, N) for c in cards],
                              1).astype(np.int32))
    fams = [(0, (1,)), (1, ()), (2, (0, 1))]
    got = S.disc_family_scores(xd, fams, cards, ess=ess)

    xnp = np.asarray(xd)
    for m, (ch, pa) in enumerate(fams):
        r = cards[ch]
        q = int(np.prod([cards[p] for p in pa])) if pa else 1
        a_j, a_jk = ess / q, ess / (q * r)
        cnt = {}
        for row in xnp:
            j = 0
            for p in pa:
                j = j * cards[p] + row[p]
            cnt[(j, row[ch])] = cnt.get((j, row[ch]), 0) + 1
        exp = 0.0
        for j in range(q):
            nij = sum(cnt.get((j, k), 0) for k in range(r))
            exp += math.lgamma(a_j) - math.lgamma(a_j + nij)
            for k in range(r):
                exp += (math.lgamma(a_jk + cnt.get((j, k), 0))
                        - math.lgamma(a_jk))
        assert abs(float(got[m]) - exp) < 1e-3


def test_disc_family_scores_backend_parity():
    rng = np.random.default_rng(2)
    cards = [3, 2, 4, 3]
    xd = jnp.asarray(np.stack([rng.integers(0, c, 800) for c in cards],
                              1).astype(np.int32))
    fams = [(i, tuple(j for j in range(4) if j != i)[:2]) for i in range(4)]
    fams += [(0, ()), (2, (1,))]
    a = S.disc_family_scores(xd, fams, cards, backend="einsum")
    b = S.disc_family_scores(xd, fams, cards, backend="pallas")
    np.testing.assert_allclose(a, b, atol=1e-3, rtol=1e-5)


def test_nig_evidence_matches_sequential_predictive():
    """Closed-form NIG evidence == prequential product of posterior-
    predictive student-t densities (the textbook identity)."""
    rng = np.random.default_rng(3)
    D, N = 3, 40
    X = rng.standard_normal((N, D))
    X[:, 0] = 1.0
    y = X @ rng.standard_normal(D) + 0.5 * rng.standard_normal(N)
    kappa, a0, b0 = 2.0, 1.5, 0.8
    ev = float(nig_evidence(jnp.asarray(X.T @ X), jnp.asarray(X.T @ y),
                            jnp.asarray(y @ y), jnp.asarray(float(N)),
                            kappa=kappa, a0=a0, b0=b0))

    def t_logpdf(x, df, loc, scale):
        z = (x - loc) / scale
        return (math.lgamma((df + 1) / 2) - math.lgamma(df / 2)
                - 0.5 * math.log(df * math.pi) - math.log(scale)
                - (df + 1) / 2 * math.log1p(z * z / df))

    K, m, a, b = kappa * np.eye(D), np.zeros(D), a0, b0
    lp = 0.0
    for i in range(N):
        x_, y_ = X[i], y[i]
        s2 = b / a * (1 + x_ @ np.linalg.solve(K, x_))
        lp += t_logpdf(y_, 2 * a, x_ @ m, math.sqrt(s2))
        Kn = K + np.outer(x_, x_)
        mn = np.linalg.solve(Kn, K @ m + x_ * y_)
        b = b + 0.5 * (y_ * y_ + m @ K @ m - mn @ Kn @ mn)
        K, m, a = Kn, mn, a + 0.5
    assert abs(ev - lp) < 1e-3


def test_nig_evidence_zero_padding_invariant():
    """Zero-padded design columns leave the evidence unchanged — the
    property that lets ragged candidate sets batch into one kernel call."""
    rng = np.random.default_rng(4)
    X = rng.standard_normal((60, 2))
    y = X @ [1.0, -0.5] + 0.3 * rng.standard_normal(60)
    args = (jnp.asarray(X.T @ X), jnp.asarray(X.T @ y), jnp.asarray(y @ y),
            jnp.asarray(60.0))
    ev = float(nig_evidence(*args, kappa=1.3))
    pad = (jnp.asarray(np.pad(X.T @ X, ((0, 3), (0, 3)))),
           jnp.asarray(np.pad(X.T @ y, (0, 3))), args[2], args[3])
    ev_pad = float(nig_evidence(*pad, kappa=1.3))
    assert abs(ev - ev_pad) < 1e-4


def test_clg_scores_prefer_true_parent():
    bn = syn.clg_tree_bn(5, seed=2)
    s = syn.bn_stream(bn, 4000, seed=3)
    b = s.collect()
    cards = []
    # the true parent must beat the empty family and (data-processing
    # inequality) every node whose tree path to the child runs THROUGH the
    # parent — i.e. the parent's other neighbors.  Nodes on the child's
    # descendant side can legitimately score higher: scores identify the
    # skeleton, not the orientation.
    adj = {int(c[1:]): set() for c in bn.dag.parents}
    for c, ps in bn.dag.parents.items():
        for p in ps:
            adj[int(c[1:])].add(int(p.name[1:]))
            adj[int(p.name[1:])].add(int(c[1:]))
    for child, ps in bn.dag.parents.items():
        if not ps:
            continue
        ci = int(child[1:])
        p = int(ps[0].name[1:])
        others = sorted(adj[p] - {ci})
        fams = ([(ci, (p,), ()), (ci, (), ())]
                + [(ci, (o,), ()) for o in others])
        sc = S.clg_family_scores(b.xc, b.xd, fams, cards)
        assert sc[0] == max(sc), (child, sc)


# ---------------------------------------------------------------------------
# Chow-Liu / TAN
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 3])
def test_chowliu_exact_discrete_tree_recovery(seed):
    """Acceptance: Chow-Liu exactly recovers a ground-truth tree from
    ample synthetic data."""
    bn = syn.random_discrete_bn(7, card=3, seed=seed, tree=True)
    stream = syn.bn_stream(bn, 6000, seed=seed + 100)
    edges, learned = chow_liu(stream, stream.attributes)
    assert undirected_edges(edges) == undirected_edges(bn)
    # the fitted network reproduces the generator's conditionals closely
    asg = {a.name: stream.collect().xd[:, i]
           for i, a in enumerate(stream.attributes)}
    lp_true = float(jnp.mean(bn.log_prob(asg)))
    lp_learn = float(jnp.mean(learned.log_prob(asg)))
    assert lp_learn > lp_true - 0.05


def test_chowliu_exact_clg_tree_recovery():
    bn = syn.clg_tree_bn(8, seed=5)
    stream = syn.bn_stream(bn, 8000, seed=2)
    edges, learned = chow_liu(stream, stream.attributes)
    assert undirected_edges(edges) == undirected_edges(bn)
    asg = {a.name: stream.collect().xc[:, i]
           for i, a in enumerate(stream.attributes)}
    assert np.isfinite(np.asarray(learned.log_prob(asg))).all()


def test_chowliu_rejects_mixed_features():
    attrs = [Attribute("G0", REAL), Attribute("D0", FINITE, 2)]
    s = DataStream.from_arrays(attrs, np.zeros((4, 1), np.float32),
                               np.zeros((4, 1), np.int32))
    with pytest.raises(ValueError, match="mixed"):
        chow_liu(s, attrs)


def test_chowliu_rejects_out_of_range_root():
    attrs = [Attribute("G0", REAL), Attribute("G1", REAL)]
    s = DataStream.from_arrays(attrs, np.zeros((8, 2), np.float32))
    with pytest.raises(ValueError, match="root"):
        chow_liu(s, attrs, root=2)


def test_tan_recovers_augmenting_tree_and_classifies():
    """TAN on data generated from a TAN structure: class -> all features,
    plus a feature chain X0 -> X1 -> X2; conditional-MI MST must find the
    chain, and the classifier must beat the class prior."""
    import jax

    from repro.core.dag import (BayesianNetwork, DAG, MultinomialCPD,
                                Variables)

    rng = np.random.default_rng(0)
    card, ncls = 3, 2
    vs = Variables()
    Y = vs.new_multinomial("Y", ncls)
    xs = [vs.new_multinomial(f"X{i}", card) for i in range(3)]
    dag = DAG(vs)
    for x in xs:
        dag.add_parent(x, Y)
    dag.add_parent(xs[1], xs[0])
    dag.add_parent(xs[2], xs[1])

    def sharp(q):
        t = 0.15 * rng.dirichlet(np.ones(card), size=q)
        for j in range(q):
            t[j, j % card] += 0.85
        return t

    cpds = {"Y": MultinomialCPD(jnp.asarray([0.6, 0.4]))}
    cpds["X0"] = MultinomialCPD(jnp.asarray(
        sharp(ncls).astype(np.float32)))
    for i in (1, 2):
        t = sharp(ncls * card).reshape(ncls, card, card)
        cpds[f"X{i}"] = MultinomialCPD(jnp.asarray(t.astype(np.float32)))
    bn = BayesianNetwork(dag, cpds)
    stream = syn.bn_stream(bn, 6000, seed=7)

    edges, learned = tan(stream, stream.attributes, "Y")
    got = {e for e in edges if "Y" not in e}
    assert undirected_edges(got) == {frozenset(("X0", "X1")),
                                frozenset(("X1", "X2"))}
    # every feature keeps the class parent
    for i in range(3):
        assert ("Y", f"X{i}") in edges

    batch = stream.collect()
    ycol = [a.name for a in stream.attributes
            if a.kind == FINITE].index("Y")
    pred = np.asarray(predict_class(learned, "Y", batch, stream.attributes))
    acc = (pred == np.asarray(batch.xd)[:, ycol]).mean()
    assert acc > 0.85


# ---------------------------------------------------------------------------
# hill-climbing
# ---------------------------------------------------------------------------


def test_hillclimb_recovers_discrete_skeleton():
    """Acceptance: F1 >= 0.9 on a bounded-fan-in random discrete BN."""
    f1s = []
    for seed in (0, 2):
        bn = syn.random_discrete_bn(6, card=3, max_parents=2, seed=seed)
        stream = syn.bn_stream(bn, 6000, seed=seed + 50)
        res = hill_climb(stream, stream.attributes, max_parents=2)
        f1s.append(skeleton_f1(undirected_edges(bn), undirected_edges(res.parents)))
    assert min(f1s) >= 0.9, f1s


def test_hillclimb_recovers_clg_tree_exactly():
    bn = syn.clg_tree_bn(6, seed=7)
    stream = syn.bn_stream(bn, 6000, seed=9)
    res = hill_climb(stream, stream.attributes, max_parents=2)
    assert undirected_edges(res.parents) == undirected_edges(bn)
    assert res.bn is not None


def test_hillclimb_respects_fan_in_and_clg_restriction():
    bn = syn.random_discrete_bn(5, card=2, max_parents=2, seed=1)
    stream = syn.bn_stream(bn, 2000, seed=4)
    res = hill_climb(stream, stream.attributes, max_parents=1)
    assert all(len(p) <= 1 for p in res.parents.values())
    # mixed data: discrete children must never gain continuous parents
    mbn = syn.clg_tree_bn(3, seed=0)
    ms = syn.bn_stream(mbn, 1500, seed=1)
    joint = DataStream.from_arrays(
        ms.attributes + [Attribute("D0", FINITE, 2)],
        np.asarray(ms.collect().xc),
        np.asarray(np.random.default_rng(0).integers(0, 2, (1500, 1)),
                   np.int32))
    res2 = hill_climb(joint, joint.attributes, max_parents=2)
    for child, ps in res2.parents.items():
        if child.startswith("D"):
            assert all(p.startswith("D") for p in ps)


def test_hillclimb_score_caching_and_monotone_trace():
    bn = syn.random_discrete_bn(5, card=2, max_parents=2, seed=3)
    stream = syn.bn_stream(bn, 3000, seed=6)
    res = hill_climb(stream, stream.attributes, max_parents=2)
    # every applied operator improved the score
    assert all(d > 0 for *_, d in res.trace)
    # cache-miss count stays far below ops * iters re-scoring
    assert res.n_scored < 5 * 2 ** 4 * max(res.n_iters, 1)


# ---------------------------------------------------------------------------
# materialization -> inference engines
# ---------------------------------------------------------------------------


def test_fit_cpds_recovers_tables():
    bn = syn.random_discrete_bn(4, card=3, seed=5, tree=True)
    stream = syn.bn_stream(bn, 20_000, seed=8)
    parents = {c: [p.name for p in ps]
               for c, ps in bn.dag.parents.items()}
    learned = fit_cpds(stream.attributes, parents, stream.collect())
    for name, cpd in bn.cpds.items():
        np.testing.assert_allclose(
            np.asarray(learned.cpds[name].table), np.asarray(cpd.table),
            atol=0.05)


def test_learned_bn_serves_exact_queries():
    """The learned network drops into infer_exact / PGMQueryEngine and its
    answers match the generator's on the same junction tree."""
    from repro.infer_exact import JunctionTreeEngine
    from repro.serve.engine import PGMQueryEngine

    bn = syn.random_discrete_bn(5, card=3, seed=0, tree=True)
    stream = syn.bn_stream(bn, 12_000, seed=1)
    _, learned = chow_liu(stream, stream.attributes)

    eng = PGMQueryEngine(learned, mode="exact")
    q = eng.submit("D0", {"D3": 1, "D4": 2})
    eng.flush()
    assert q.done and q.result.shape == (3,)
    np.testing.assert_allclose(q.result.sum(), 1.0, atol=1e-5)

    ref = JunctionTreeEngine(bn)
    ref.set_evidence({"D3": 1, "D4": 2})
    ref.run_inference()
    exact = np.asarray(ref.posterior_discrete(
        bn.dag.variables.by_name("D0")))
    np.testing.assert_allclose(q.result, exact, atol=0.06)


# ---------------------------------------------------------------------------
# drift-triggered re-search (stream_adapt)
# ---------------------------------------------------------------------------


def test_drift_triggers_structure_switch():
    """Acceptance: the generating network changes mid-stream; the PH
    monitor fires, the window resets, and the re-searched structure
    matches the new generator — with the relearned BayesianNetwork
    answering queries through the exact engine unchanged."""
    from repro.serve.engine import PGMQueryEngine

    bn_a = syn.random_discrete_bn(5, card=3, seed=0, tree=True)
    bn_b = syn.random_discrete_bn(5, card=3, seed=11, tree=True)
    ea, eb = undirected_edges(bn_a), undirected_edges(bn_b)
    assert ea != eb                   # the concept switch is observable
    stream = DataStream.concat([syn.bn_stream(bn_a, 6000, seed=1),
                                syn.bn_stream(bn_b, 6000, seed=2)])

    ad = AdaptiveStructure(stream.attributes, learner="chowliu",
                           window=4000, drift_threshold=3.0)
    drift_batches, structures = [], {}
    for i, b in enumerate(stream.batches(500)):
        info = ad.update(b)
        structures[i] = undirected_edges({(u, v) for u, v in ad.edges()})
        if info["drifted"]:
            drift_batches.append(i)
    assert drift_batches and drift_batches[0] >= 12   # not before the switch
    assert ad.n_drifts >= 1
    assert structures[10] == ea                       # pre-drift: concept A
    assert structures[max(structures)] == eb          # post-drift: concept B

    eng = PGMQueryEngine(ad.bn, mode="exact")
    q = eng.submit("D0", {"D1": 0})
    eng.flush()
    assert q.done and abs(float(q.result.sum()) - 1.0) < 1e-5


def test_adaptive_structure_hillclimb_learner_smoke():
    bn = syn.random_discrete_bn(4, card=2, seed=2, tree=True)
    stream = syn.bn_stream(bn, 3000, seed=5)
    # relearn_every exercises the scheduled re-search path, including the
    # stats-reuse shortcut when the search keeps the structure unchanged
    ad = AdaptiveStructure(stream.attributes, learner="hillclimb",
                           window=3000, max_parents=2, relearn_every=2)
    ad.fit_stream(stream, batch_size=750)
    assert ad.bn is not None and ad.n_relearn >= 2
    assert skeleton_f1(undirected_edges(bn), undirected_edges(ad.parents)) >= 0.5


def test_incremental_refit_matches_one_shot_fit():
    """The streaming CPD refit (sum of per-chunk structure_stats) must
    equal fit_cpds on the concatenated window — the additivity that makes
    per-batch cost O(batch) instead of O(window).  Non-default ``ess``
    checks the refit and the relearn share one smoothing regime."""
    bn = syn.random_discrete_bn(4, card=3, seed=6, tree=True)
    stream = syn.bn_stream(bn, 4000, seed=7)
    ad = AdaptiveStructure(stream.attributes, learner="chowliu",
                           window=4000, ess=5.0)
    for b in stream.batches(500):
        ad.update(b)
    oneshot = fit_cpds(stream.attributes,
                       {k: list(v) for k, v in ad.parents.items()},
                       ad._window_batch(), ess=5.0)
    for name, cpd in oneshot.cpds.items():
        np.testing.assert_allclose(np.asarray(ad.bn.cpds[name].table),
                                   np.asarray(cpd.table), atol=1e-5)


def test_adaptive_structure_rejects_bad_config():
    attrs = [Attribute("D0", FINITE, 2)]
    with pytest.raises(ValueError, match="unknown learner"):
        AdaptiveStructure(attrs, learner="magic")
    with pytest.raises(ValueError, match="class_name"):
        AdaptiveStructure(attrs, learner="tan")


# ---------------------------------------------------------------------------
# satellites riding along
# ---------------------------------------------------------------------------


def test_topological_order_deep_chain_iterative():
    """Structure search generates deep chains; topological_order must not
    hit Python's recursion limit (it used to at ~330 nodes)."""
    from repro.core.dag import DAG, Variables

    n = 3000
    vs = Variables()
    nodes = [vs.new_multinomial(f"V{i}", 2) for i in range(n)]
    dag = DAG(vs)
    for a, b in zip(nodes, nodes[1:]):
        dag.add_parent(b, a)
    order = dag.topological_order()
    assert [v.name for v in order] == [f"V{i}" for i in range(n)]
    # cycle detection still works on the iterative path
    dag.parents["V0"].append(nodes[-1])
    with pytest.raises(ValueError, match="cycle"):
        dag.topological_order()


def test_datastream_concat_rejects_schema_mismatch():
    a1 = [Attribute("X", REAL)]
    a2 = [Attribute("X", FINITE, 2)]
    s1 = DataStream.from_arrays(a1, np.zeros((3, 1), np.float32))
    s2 = DataStream.from_arrays(a2, np.zeros((3, 0), np.float32),
                                np.zeros((3, 1), np.int32))
    with pytest.raises(ValueError, match="schema"):
        DataStream.concat([s1, s2])
    with pytest.raises(ValueError, match="zero"):
        DataStream.concat([])
    # matching schemas still concatenate
    s3 = DataStream.from_arrays(a1, np.ones((2, 1), np.float32))
    cat = DataStream.concat([s1, s3])
    assert cat.collect().xc.shape == (5, 1)
