"""Streaming VB (Eq. 3), drift detection, SVI."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import streaming, svi, vmp
from repro.core.dag import PlateSpec
from repro.data.synthetic import drift_stream, gmm_stream


def _setup(f=3, k=2, seed=0):
    spec = PlateSpec(n_features=f, latent_card=k)
    cp = vmp.compile_plate(spec)
    prior = vmp.default_prior(cp)
    init = vmp.symmetry_broken(prior, jax.random.PRNGKey(seed))
    return cp, prior, init


def test_streaming_matches_batch_on_stationary_data():
    stream, means, _ = gmm_stream(1600, 2, 3, seed=7)
    cp, prior, init = _setup()
    # batch fit
    full = stream.collect()
    st = vmp.vmp_fit(cp, prior, init, full.xc, full.xd, 100, 1e-6)
    # streaming fit, 8 batches of 200
    ss = streaming.stream_init(prior, init)
    for b in stream.batches(200):
        ss, info = streaming.stream_update(cp, prior, ss, b.xc, b.xd)
    m_batch = np.sort(np.asarray(st.post.reg.m[:, :, 0]).ravel())
    m_stream = np.sort(np.asarray(ss.post.reg.m[:, :, 0]).ravel())
    np.testing.assert_allclose(m_stream, m_batch, atol=0.2)
    assert int(ss.n_drifts) == 0


def test_drift_detection_fires_on_shift():
    stream, n_phase = drift_stream(1500, 3, seed=8)
    cp, prior, init = _setup(k=1)
    ss = streaming.stream_init(prior, init)
    drift_batches = []
    for i, b in enumerate(stream.batches(250)):
        ss, info = streaming.stream_update(cp, prior, ss, b.xc, b.xd,
                                           drift_threshold=3.0)
        if bool(info["drifted"]):
            drift_batches.append(i)
    # phase flips at batch 6 (1500/250); drift must fire shortly after
    assert drift_batches, "no drift detected"
    assert min(drift_batches) in (6, 7), drift_batches
    # and the model must have re-adapted to the new mean (+6 shift)
    final_means = np.asarray(ss.post.reg.m[:, 0, 0])
    assert (final_means > 2.0).all(), final_means


def test_svi_converges_to_batch_posterior():
    stream, means, _ = gmm_stream(2000, 2, 3, seed=9)
    cp, prior, init = _setup(seed=1)
    full = stream.collect()
    st = vmp.vmp_fit(cp, prior, init, full.xc, full.xd, 100, 1e-6)
    state = svi.svi_init(init)
    for epoch in range(6):
        for b in stream.batches(250):
            state = svi.svi_step(cp, prior, state, b.xc, b.xd, 2000.0)
    post = svi.svi_posterior(state)
    m_b = np.sort(np.asarray(st.post.reg.m[:, :, 0]).ravel())
    m_s = np.sort(np.asarray(post.reg.m[:, :, 0]).ravel())
    np.testing.assert_allclose(m_s, m_b, atol=0.25)


def test_natural_coordinate_roundtrip():
    cp, prior, init = _setup()
    nat = svi.to_natural(init)
    back = svi.from_natural(nat)
    for a, b in zip(jax.tree_util.tree_leaves(init),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)


# -- Model.update_model streaming wiring (stream_fit underneath) --------------


def test_update_model_multibatch_stream_uses_stream_fit():
    """A multi-chunk DataStream routes through the resident stream_fit scan
    and matches the explicit per-batch stream_update loop."""
    from repro.data.stream import DataStream
    from repro.pgm_models import GaussianMixture

    full, _, _ = gmm_stream(1200, 2, 3, seed=11)
    batch = full.collect()
    xc = np.asarray(batch.xc)
    parts = [DataStream.from_arrays(full.attributes, xc[i:i + 300])
             for i in range(0, 1200, 300)]
    multi = DataStream.concat(parts)          # source yields 4 equal chunks

    m = GaussianMixture(full.attributes, n_states=2, seed=0)
    e = m.update_model(multi, sweeps=8)
    assert np.isfinite(e)
    assert m.n_seen == 1200

    # reference: the explicit per-batch streaming loop (same step body)
    ref = GaussianMixture(full.attributes, n_states=2, seed=0)
    ss = streaming.stream_init(ref._chained_prior, ref.posterior)
    for i in range(0, 1200, 300):
        ss, info = streaming.stream_update(
            ref.cp, ref.prior, ss, jnp.asarray(xc[i:i + 300]),
            jnp.zeros((300, 0), jnp.int32), sweeps=8)
    np.testing.assert_allclose(np.asarray(m.posterior.reg.m),
                               np.asarray(ss.post.reg.m), atol=2e-3)
    np.testing.assert_allclose(e, float(info["elbo"]), atol=2.0)


def test_update_model_stream_window_matches_full_scan():
    """stream_window= replays the same stream in device-sliced windows and
    lands on the same posterior as the whole-stream-resident scan."""
    from repro.data.stream import DataStream
    from repro.pgm_models import GaussianMixture

    full, _, _ = gmm_stream(1200, 2, 3, seed=12)
    batch = full.collect()
    xc = np.asarray(batch.xc)
    parts = [DataStream.from_arrays(full.attributes, xc[i:i + 300])
             for i in range(0, 1200, 300)]

    m = GaussianMixture(full.attributes, n_states=2, seed=0)
    e = m.update_model(DataStream.concat(parts), sweeps=8)
    mw = GaussianMixture(full.attributes, n_states=2, seed=0)
    ew = mw.update_model(DataStream.concat(parts), sweeps=8, stream_window=2)
    np.testing.assert_allclose(np.asarray(m.posterior.reg.m),
                               np.asarray(mw.posterior.reg.m),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(e, ew, atol=1e-3)
    assert mw.n_seen == 1200


def test_update_model_ragged_stream_falls_back_to_per_batch():
    from repro.data.stream import DataStream
    from repro.pgm_models import GaussianMixture

    full, _, _ = gmm_stream(900, 2, 3, seed=12)
    xc = np.asarray(full.collect().xc)
    parts = [DataStream.from_arrays(full.attributes, xc[:500]),
             DataStream.from_arrays(full.attributes, xc[500:])]  # 500 + 400
    multi = DataStream.concat(parts)
    m = GaussianMixture(full.attributes, n_states=2, seed=0)
    e = m.update_model(multi, sweeps=8)
    assert np.isfinite(e)
    assert m.n_seen == 900


def test_update_model_single_chunk_stream_keeps_batch_path():
    """from_arrays streams yield one chunk -> the one-shot VMP fit."""
    from repro.pgm_models import GaussianMixture

    s, _, _ = gmm_stream(600, 2, 3, seed=13)
    m = GaussianMixture(s.attributes, n_states=2, seed=0)
    e = m.update_model(s, sweeps=30)
    assert np.isfinite(e)
    assert m.n_seen == 600
