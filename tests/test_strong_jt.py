"""Strong junction tree (Lauritzen 1992): CLG networks with unobserved
continuous INTERNAL nodes, verified against the full-CLG brute oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dag import (BayesianNetwork, CLGCPD, DAG, MultinomialCPD,
                            Variables)
from repro.infer_exact import (JunctionTreeEngine, brute_posterior,
                               brute_posterior_mean_var,
                               compile_strong_junction_tree)
from repro.infer_exact.brute import brute_log_evidence
from repro.infer_exact.graph import (verify_running_intersection,
                                     verify_strong)


def chain_net():
    """Z -> X1 -> X2 -> X3: X2 is an unobserved continuous INTERNAL node
    once evidence lands on X1/X3 only."""
    vs = Variables()
    Z = vs.new_multinomial("Z", 3)
    X1, X2, X3 = (vs.new_gaussian(n) for n in ("X1", "X2", "X3"))
    dag = DAG(vs)
    dag.add_parent(X1, Z)
    dag.add_parent(X2, X1)
    dag.add_parent(X2, Z)
    dag.add_parent(X3, X2)
    bn = BayesianNetwork(dag, {
        "Z": MultinomialCPD(jnp.array([0.5, 0.3, 0.2])),
        "X1": CLGCPD(jnp.array([0., 2., -1.]), jnp.zeros((3, 0)),
                     jnp.array([1.0, 0.5, 2.0])),
        "X2": CLGCPD(jnp.array([1., -1., 0.]),
                     jnp.array([[0.5], [1.5], [-0.7]]),
                     jnp.array([0.8, 1.2, 0.3])),
        "X3": CLGCPD(jnp.asarray(0.5), jnp.asarray([1.1]),
                     jnp.asarray(0.6)),
    })
    return bn, Z, X1, X2, X3


def vstruct_net():
    """H1 -> X <- H2 with latent continuous parents (v-structure)."""
    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    H1, H2, X = (vs.new_gaussian(n) for n in ("H1", "H2", "X"))
    dag = DAG(vs)
    dag.add_parent(H1, Z)
    dag.add_parent(X, H1)
    dag.add_parent(X, H2)
    bn = BayesianNetwork(dag, {
        "Z": MultinomialCPD(jnp.array([0.6, 0.4])),
        "H1": CLGCPD(jnp.array([0., 3.]), jnp.zeros((2, 0)),
                     jnp.array([1.0, 0.5])),
        "H2": CLGCPD(jnp.asarray(-1.0), jnp.zeros((0,)), jnp.asarray(2.0)),
        "X": CLGCPD(jnp.asarray(0.2), jnp.asarray([0.8, -1.2]),
                    jnp.asarray(0.4)),
    })
    return bn, Z, H1, H2, X


def fa_net(seed=0, F=3):
    """2-layer FA-style: Z mixes the 2-d latent (H1, H2); X_i = b_i^T H."""
    rng = np.random.RandomState(seed)
    vs = Variables()
    Z = vs.new_multinomial("Z", 3)
    H1, H2 = vs.new_gaussian("H1"), vs.new_gaussian("H2")
    xs = [vs.new_gaussian(f"X{i}") for i in range(F)]
    dag = DAG(vs)
    dag.add_parent(H1, Z)
    dag.add_parent(H2, Z)
    cpds = {
        "Z": MultinomialCPD(jnp.asarray(rng.dirichlet(np.ones(3)))),
        "H1": CLGCPD(jnp.asarray(rng.randn(3)), jnp.zeros((3, 0)),
                     jnp.ones(3)),
        "H2": CLGCPD(jnp.asarray(rng.randn(3)), jnp.zeros((3, 0)),
                     jnp.asarray([0.5, 1.5, 1.0])),
    }
    for x in xs:
        dag.add_parent(x, H1)
        dag.add_parent(x, H2)
        cpds[x.name] = CLGCPD(jnp.asarray(rng.randn()),
                              jnp.asarray(rng.randn(2)),
                              jnp.asarray(0.3 + rng.rand()))
    return BayesianNetwork(dag, cpds), Z, H1, H2, xs


# -- acceptance criterion: strong JT == brute on unobserved cont internals ---


def test_strong_chain_matches_brute():
    bn, Z, X1, X2, X3 = chain_net()
    eng = JunctionTreeEngine(bn)
    assert eng.strong
    ev = {"X1": 0.7, "X3": -0.4}
    eng.set_evidence(ev)
    eng.run_inference()
    np.testing.assert_allclose(np.asarray(eng.posterior_discrete(Z)),
                               np.asarray(brute_posterior(bn, Z, ev)),
                               atol=1e-5)
    m, v = eng.posterior_mean_var(X2)
    mb, vb = brute_posterior_mean_var(bn, X2, ev)
    np.testing.assert_allclose(float(m), float(mb), atol=1e-5)
    np.testing.assert_allclose(float(v), float(vb), atol=1e-5)
    np.testing.assert_allclose(float(eng.log_evidence()),
                               float(brute_log_evidence(bn, ev)), atol=1e-5)


def test_strong_vstructure_matches_brute():
    bn, Z, H1, H2, X = vstruct_net()
    eng = JunctionTreeEngine(bn)
    ev = {"X": 1.3}
    eng.set_evidence(ev)
    eng.run_inference()
    np.testing.assert_allclose(np.asarray(eng.posterior_discrete(Z)),
                               np.asarray(brute_posterior(bn, Z, ev)),
                               atol=1e-5)
    for q in (H1, H2):
        m, v = eng.posterior_mean_var(q)
        mb, vb = brute_posterior_mean_var(bn, q, ev)
        np.testing.assert_allclose(float(m), float(mb), atol=1e-5)
        np.testing.assert_allclose(float(v), float(vb), atol=1e-5)


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_strong_fa_style_matches_brute(seed):
    bn, Z, H1, H2, xs = fa_net(seed)
    rng = np.random.RandomState(100 + seed)
    ev = {x.name: float(rng.randn() * 1.5) for x in xs}
    eng = JunctionTreeEngine(bn)
    eng.set_evidence(ev)
    eng.run_inference()
    np.testing.assert_allclose(np.asarray(eng.posterior_discrete(Z)),
                               np.asarray(brute_posterior(bn, Z, ev)),
                               atol=1e-5)
    for q in (H1, H2):
        m, v = eng.posterior_mean_var(q)
        mb, vb = brute_posterior_mean_var(bn, q, ev)
        np.testing.assert_allclose(float(m), float(mb), atol=1e-5)
        np.testing.assert_allclose(float(v), float(vb), atol=1e-5)
    np.testing.assert_allclose(float(eng.log_evidence()),
                               float(brute_log_evidence(bn, ev)), atol=1e-4)


def test_strong_partial_evidence_and_discrete_evidence():
    """Mixed schema: some leaves observed, discrete evidence clamped."""
    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    W = vs.new_multinomial("W", 3)
    H = vs.new_gaussian("H")
    X1, X2 = vs.new_gaussian("X1"), vs.new_gaussian("X2")
    dag = DAG(vs)
    dag.add_parent(H, Z)
    dag.add_parent(X1, H)
    dag.add_parent(X1, W)
    dag.add_parent(X2, H)
    rng = np.random.RandomState(1)
    bn = BayesianNetwork(dag, {
        "Z": MultinomialCPD(jnp.array([0.3, 0.7])),
        "W": MultinomialCPD(jnp.asarray(rng.dirichlet(np.ones(3)))),
        "H": CLGCPD(jnp.array([0., 2.5]), jnp.zeros((2, 0)),
                    jnp.array([1.0, 0.6])),
        "X1": CLGCPD(jnp.asarray(rng.randn(3)), jnp.asarray(rng.randn(3, 1)),
                     jnp.asarray(0.5 + rng.rand(3))),
        "X2": CLGCPD(jnp.asarray(0.1), jnp.asarray([1.3]), jnp.asarray(0.7)),
    })
    ev = {"X1": 0.5, "W": 2}
    eng = JunctionTreeEngine(bn)
    eng.set_evidence(ev)
    eng.run_inference()
    np.testing.assert_allclose(np.asarray(eng.posterior_discrete(Z)),
                               np.asarray(brute_posterior(bn, Z, ev)),
                               atol=1e-5)
    for q in (H, X2):
        m, v = eng.posterior_mean_var(q)
        mb, vb = brute_posterior_mean_var(bn, q, ev)
        np.testing.assert_allclose(float(m), float(mb), atol=1e-5)
        np.testing.assert_allclose(float(v), float(vb), atol=1e-5)


# -- batched evidence: shapes and per-instance agreement ----------------------


def test_strong_batched_evidence_shapes_and_values():
    bn, Z, H1, H2, xs = fa_net(3)
    B = 6
    rng = np.random.RandomState(7)
    ev = {x.name: rng.randn(B).astype(np.float32) for x in xs}
    eng = JunctionTreeEngine(bn)
    eng.set_evidence(ev)
    eng.run_inference()
    pz = np.asarray(eng.posterior_discrete(Z))
    m, v = eng.posterior_mean_var(H1)
    lz = np.asarray(eng.log_evidence())
    assert pz.shape == (B, 3)
    assert np.shape(m) == (B,) and np.shape(v) == (B,)
    assert lz.shape == (B,)
    np.testing.assert_allclose(pz.sum(-1), 1.0, atol=1e-5)
    for b in range(B):
        ev1 = {k: float(a[b]) for k, a in ev.items()}
        np.testing.assert_allclose(pz[b],
                                   np.asarray(brute_posterior(bn, Z, ev1)),
                                   atol=1e-5)
        mb, vb = brute_posterior_mean_var(bn, H1, ev1)
        np.testing.assert_allclose(float(m[b]), float(mb), atol=1e-5)
        np.testing.assert_allclose(float(v[b]), float(vb), atol=1e-5)


def test_strong_pallas_weak_marginal_matches_jnp():
    bn, Z, H1, H2, xs = fa_net(4)
    rng = np.random.RandomState(9)
    ev = {x.name: rng.randn(4).astype(np.float32) for x in xs[:2]}
    ref = JunctionTreeEngine(bn, use_pallas=False)
    ref.set_evidence(ev)
    ref.run_inference()
    pal = JunctionTreeEngine(bn, use_pallas=True)
    pal.set_evidence(ev)
    pal.run_inference()
    np.testing.assert_allclose(np.asarray(pal.posterior_discrete(Z)),
                               np.asarray(ref.posterior_discrete(Z)),
                               atol=1e-5)
    for q in (H1, H2, xs[2]):
        mr, vr = ref.posterior_mean_var(q)
        mp, vp = pal.posterior_mean_var(q)
        np.testing.assert_allclose(np.asarray(mp), np.asarray(mr), atol=1e-5)
        np.testing.assert_allclose(np.asarray(vp), np.asarray(vr), atol=1e-5)


def test_strong_multi_discrete_parents_nonsorted_order():
    """Discrete CPD tables are laid out in RAW get_parents order; the strong
    pipeline must permute them onto its sorted scopes (regression: a node
    with parents added as (B, A) silently mislabeled its table axes)."""
    vs = Variables()
    B_ = vs.new_multinomial("B", 2)
    A_ = vs.new_multinomial("A", 2)
    D_ = vs.new_multinomial("D", 2)
    H = vs.new_gaussian("H")
    X = vs.new_gaussian("X")
    dag = DAG(vs)
    dag.add_parent(D_, B_)          # raw parent order (B, A) != sorted (A, B)
    dag.add_parent(D_, A_)
    dag.add_parent(H, D_)
    dag.add_parent(X, H)            # cont-cont edge -> strong pipeline
    rng = np.random.RandomState(5)
    table = rng.dirichlet(np.ones(2), size=(2, 2))     # [card(B), card(A), 2]
    bn = BayesianNetwork(dag, {
        "B": MultinomialCPD(jnp.array([0.7, 0.3])),
        "A": MultinomialCPD(jnp.array([0.2, 0.8])),
        "D": MultinomialCPD(jnp.asarray(table)),
        "H": CLGCPD(jnp.array([-2.0, 2.0]), jnp.zeros((2, 0)),
                    jnp.array([1.0, 0.5])),
        "X": CLGCPD(jnp.asarray(0.3), jnp.asarray([1.5]), jnp.asarray(0.4)),
    })
    eng = JunctionTreeEngine(bn)
    assert eng.strong
    ev = {"X": 1.0}
    eng.set_evidence(ev)
    eng.run_inference()
    for var in (D_, A_, B_):
        np.testing.assert_allclose(
            np.asarray(eng.posterior_discrete(var)),
            np.asarray(brute_posterior(bn, var, ev)), atol=1e-5)
    m, v = eng.posterior_mean_var(H)
    mb, vb = brute_posterior_mean_var(bn, H, ev)
    np.testing.assert_allclose(float(m), float(mb), atol=1e-5)
    np.testing.assert_allclose(float(v), float(vb), atol=1e-5)


# -- shape-bucketed propagation == per-clique reference -----------------------


def _deep_chain_net(depth=10, K=3, seed=0):
    """Z -> X00 -> X01 -> ...: one clique per edge — the deep-tree case the
    level bucketing exists for."""
    rng = np.random.RandomState(seed)
    vs = Variables()
    Z = vs.new_multinomial("Z", K)
    xs = [vs.new_gaussian(f"X{i:02d}") for i in range(depth)]
    dag = DAG(vs)
    dag.add_parent(xs[0], Z)
    for a, b in zip(xs, xs[1:]):
        dag.add_parent(b, a)
    cpds = {"Z": MultinomialCPD(jnp.asarray(rng.dirichlet(np.ones(K)))),
            xs[0].name: CLGCPD(jnp.asarray(rng.randn(K)),
                               jnp.zeros((K, 0)), jnp.ones(K))}
    for a, b in zip(xs, xs[1:]):
        cpds[b.name] = CLGCPD(jnp.asarray(rng.randn()),
                              jnp.asarray(rng.randn(1) * 0.8),
                              jnp.asarray(0.3 + rng.rand()))
    return BayesianNetwork(dag, cpds), Z, xs


def _run_both(bn, ev):
    outs = []
    for bucketed in (False, True):
        eng = JunctionTreeEngine(bn, bucketed=bucketed)
        eng.set_evidence(ev)
        eng.run_inference()
        outs.append(eng)
    return outs


@pytest.mark.parametrize("fixture", ["chain", "vstruct", "fa"])
def test_bucketed_propagation_matches_per_clique(fixture):
    """Shape-bucketed (stacked solve/slogdet/weak-marginal) propagation
    returns the same posteriors as the per-clique reference schedule on
    every strong fixture."""
    if fixture == "chain":
        bn, Z, X1, X2, X3 = chain_net()
        ev = {"X1": 0.7, "X3": -0.4}
        queries = [X2]
    elif fixture == "vstruct":
        bn, Z, H1, H2, X = vstruct_net()
        ev = {"X": 1.3}
        queries = [H1, H2]
    else:
        bn, Z, H1, H2, xs = fa_net(1)
        rng = np.random.RandomState(11)
        ev = {x.name: float(rng.randn()) for x in xs}
        queries = [H1, H2]
    refe, buck = _run_both(bn, ev)
    np.testing.assert_allclose(np.asarray(buck.posterior_discrete(Z)),
                               np.asarray(refe.posterior_discrete(Z)),
                               atol=1e-6)
    for q in queries:
        mr, vr = refe.posterior_mean_var(q)
        mb, vb = buck.posterior_mean_var(q)
        np.testing.assert_allclose(float(mb), float(mr), atol=1e-5)
        np.testing.assert_allclose(float(vb), float(vr), atol=1e-5)
    np.testing.assert_allclose(float(buck.log_evidence()),
                               float(refe.log_evidence()), atol=1e-5)


def test_bucketed_deep_chain_batched_matches_brute():
    """Deep chain (real multi-clique levels), batched evidence: bucketed
    propagation equals both the per-clique schedule and the brute oracle."""
    bn, Z, xs = _deep_chain_net(depth=10)
    B = 4
    rng = np.random.RandomState(3)
    ev = {xs[-1].name: rng.randn(B).astype(np.float32),
          xs[4].name: rng.randn(B).astype(np.float32)}
    refe, buck = _run_both(bn, ev)
    pz_r = np.asarray(refe.posterior_discrete(Z))
    pz_b = np.asarray(buck.posterior_discrete(Z))
    np.testing.assert_allclose(pz_b, pz_r, atol=1e-6)
    mr, vr = refe.posterior_mean_var(xs[0])
    mb, vb = buck.posterior_mean_var(xs[0])
    np.testing.assert_allclose(np.asarray(mb), np.asarray(mr), atol=1e-5)
    np.testing.assert_allclose(np.asarray(vb), np.asarray(vr), atol=1e-5)
    for b in range(B):
        ev1 = {k: float(a[b]) for k, a in ev.items()}
        np.testing.assert_allclose(pz_b[b],
                                   np.asarray(brute_posterior(bn, Z, ev1)),
                                   atol=1e-5)
        m1, v1 = brute_posterior_mean_var(bn, xs[0], ev1)
        np.testing.assert_allclose(float(mb[b]), float(m1), atol=1e-5)
        np.testing.assert_allclose(float(vb[b]), float(v1), atol=1e-5)


def test_bucketed_with_pallas_weak_marginal():
    """Bucketing composes with the Pallas cg_weak_marg dispatch."""
    bn, Z, xs = _deep_chain_net(depth=8, seed=2)
    ev = {xs[-1].name: np.asarray([0.4, -0.9], np.float32)}
    refe = JunctionTreeEngine(bn, bucketed=False, use_pallas=False)
    refe.set_evidence(ev)
    refe.run_inference()
    buck = JunctionTreeEngine(bn, bucketed=True, use_pallas=True)
    buck.set_evidence(ev)
    buck.run_inference()
    np.testing.assert_allclose(np.asarray(buck.posterior_discrete(Z)),
                               np.asarray(refe.posterior_discrete(Z)),
                               atol=1e-5)
    m_r, v_r = refe.posterior_mean_var(xs[2])
    m_b, v_b = buck.posterior_mean_var(xs[2])
    np.testing.assert_allclose(np.asarray(m_b), np.asarray(m_r), atol=1e-5)
    np.testing.assert_allclose(np.asarray(v_b), np.asarray(v_r), atol=1e-5)


# -- compilation structure ---------------------------------------------------


def test_strong_tree_structure():
    bn, *_ = chain_net()
    jt = compile_strong_junction_tree(bn)
    assert len(jt.edges) == len(jt.cliques) - 1
    verify_running_intersection(jt.cliques, jt.edges)
    verify_strong(jt.cliques, jt.edges, jt.sepsets, set(jt.continuous))
    # strong elimination: every continuous variable before any discrete one
    order = jt.elimination_order
    cont = set(jt.continuous)
    last_cont = max(i for i, v in enumerate(order) if v in cont)
    first_disc = min(i for i, v in enumerate(order) if v not in cont)
    assert last_cont < first_disc
    # every family lives inside one clique
    for v in bn.order:
        fam = {v.name} | {p.name for p in bn.dag.get_parents(v)}
        assert any(fam <= c for c in jt.cliques)


def test_strong_verifier_catches_violation():
    cliques = [frozenset({"d1", "x"}), frozenset({"x", "d2"})]
    with pytest.raises(AssertionError, match="strong-root"):
        verify_strong(cliques, [(0, 1)], [frozenset({"x"})], {"x"})


def test_discrete_networks_keep_discrete_pipeline():
    """Mixture-style networks (no cont-cont edges) stay on the fast
    discrete pipeline."""
    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    X = vs.new_gaussian("X")
    dag = DAG(vs)
    dag.add_parent(X, Z)
    bn = BayesianNetwork(dag, {
        "Z": MultinomialCPD(jnp.array([0.4, 0.6])),
        "X": CLGCPD(jnp.array([0., 1.]), jnp.zeros((2, 0)),
                    jnp.array([1., 1.]))})
    eng = JunctionTreeEngine(bn)
    assert not eng.strong


# -- serve-layer wiring: strong networks behind PGMQueryEngine ----------------


def test_pgm_query_engine_on_strong_network():
    from repro.serve.engine import PGMQueryEngine

    bn, Z, X1, X2, X3 = chain_net()
    eng = PGMQueryEngine(bn, mode="exact")
    q1 = eng.submit("Z", {"X1": 0.7, "X3": -0.4})
    q2 = eng.submit("Z", {"X1": -1.2, "X3": 0.9})
    q3 = eng.submit("Z", {"X3": 0.1})             # different schema
    done = eng.flush()
    assert len(done) == 3 and all(q.done for q in done)
    for q in (q1, q2):
        ev = {k: float(v) for k, v in q.evidence.items()}
        np.testing.assert_allclose(q.result,
                                   np.asarray(brute_posterior(bn, Z, ev)),
                                   atol=1e-5)
        np.testing.assert_allclose(q.log_evidence,
                                   float(brute_log_evidence(bn, ev)),
                                   atol=1e-4)
    np.testing.assert_allclose(
        q3.result, np.asarray(brute_posterior(bn, Z, {"X3": 0.1})),
        atol=1e-5)
