"""Serving tier: request lifecycle, continuous batching, the plan/run API,
async deadline-aware micro-batching, hot model swap, replica sharding."""

import threading
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import synthetic as syn
from repro.nn import transformer as T
from repro.serve.engine import DecodeEngine, PGMQueryEngine, Request
from repro.serve.plan import CompiledPlan, PlanCache, PlanKey
from repro.serve.queue import AsyncPGMServer


def _engine(arch="granite-3-2b", batch=2, capacity=64):
    cfg = get_config(arch).reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return DecodeEngine(params, cfg, batch, capacity), cfg


def test_engine_drains_all_requests():
    eng, cfg = _engine()
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=6))
    reqs = list(eng.queue)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out)


def test_engine_continuous_batching_reuses_slots():
    eng, _ = _engine(batch=2)
    reqs = [Request(rid=i, prompt=[i + 1], max_new=3) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)   # 6 requests through 2 slots


def test_greedy_engine_matches_direct_decode():
    """A single request in slot 0 must reproduce plain greedy decoding."""
    cfg = get_config("granite-3-2b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt, n_new = [5, 9, 2], 5
    # direct
    state = T.init_decode_state(params, cfg, 1, 64)
    toks = []
    cur = jnp.asarray([[prompt[0]]], jnp.int32)
    pending = prompt[1:]
    for _ in range(len(prompt) + n_new - 1):
        logits, state = T.decode_step(params, state, cur, cfg)
        if pending:
            cur = jnp.asarray([[pending.pop(0)]], jnp.int32)
        else:
            nxt = int(logits[0, 0].argmax())
            toks.append(nxt)
            cur = jnp.asarray([[nxt]], jnp.int32)
            if len(toks) == n_new:
                break
    # engine (batch=1)
    eng = DecodeEngine(params, cfg, 1, 64)
    req = Request(rid=0, prompt=list(prompt), max_new=n_new)
    eng.submit(req)
    eng.run()
    assert req.out == toks, (req.out, toks)


# ---------------------------------------------------------------------------
# plan API (repro.serve.plan)
# ---------------------------------------------------------------------------


def _key(i, version=0, mode="jt-discrete"):
    return PlanKey(version, mode, (f"D{i}",), (4,), ("float32",))


def test_plan_cache_hit_miss_counters_and_compile_timing():
    cache = PlanCache(max_plans=8)
    assert cache.get(_key(0)) is None           # miss, no build
    plan = cache.get(_key(0), lambda: (lambda x: x + 1))
    assert isinstance(plan, CompiledPlan)
    assert plan.compile_us > 0.0
    assert plan.run(1) == 2 and plan.runs == 1
    again = cache.get(_key(0), lambda: (lambda x: x + 100))
    assert again is plan                        # hit: build never called
    st = cache.stats()
    assert st == {"hits": 1, "misses": 2, "evictions": 0, "size": 1,
                  "max_plans": 8, "hit_rate": 1 / 3, "retries": 0}
    # peek touches neither counters nor LRU order
    assert cache.peek(_key(0)) is plan
    assert cache.stats()["hits"] == 1


def test_plan_cache_lru_eviction():
    cache = PlanCache(max_plans=3)
    for i in range(3):
        cache.get(_key(i), lambda: (lambda x: x))
    cache.get(_key(0))                          # refresh 0 -> LRU order 1,2,0
    cache.get(_key(3), lambda: (lambda x: x))   # evicts 1
    assert cache.stats()["evictions"] == 1
    assert _key(1) not in cache
    assert all(k in cache for k in (_key(0), _key(2), _key(3)))


def test_plan_cache_invalidate_by_network_version():
    cache = PlanCache()
    for v in (0, 0, 1):
        for i in range(2):
            cache.get(_key(i, version=v), lambda: (lambda x: x))
    assert len(cache) == 4
    assert cache.invalidate(0) == 2             # the hot-swap drain path
    assert all(k.network_version == 1 for k in cache.keys())
    assert cache.invalidate() == 2              # drop-all flavor
    assert len(cache) == 0


# ---------------------------------------------------------------------------
# PGMQueryEngine on the plan cache
# ---------------------------------------------------------------------------


def _discrete_bn(seed=0):
    return syn.random_discrete_bn(5, card=2, max_parents=2, seed=seed)


def test_flush_returns_submission_order_for_interleaved_schemas():
    """Regression: flush() used to return bucket order — results must come
    back keyed by request id (submission order) under schema interleave."""
    bn = _discrete_bn()
    names = [v.name for v in bn.order]
    eng = PGMQueryEngine(bn, mode="exact")
    schemas = [{names[0]: 1.0}, {names[1]: 0.0, names[2]: 1.0}]
    qs = [eng.submit(names[-1], schemas[i % 2]) for i in range(7)]
    done = eng.flush()
    assert [q.qid for q in done] == [q.qid for q in qs]
    assert all(q.done for q in done)
    # and per-request answers match a bucket-homogeneous run
    ref = PGMQueryEngine(bn, mode="exact")
    for i in (0, 1):
        r = ref.submit(names[-1], schemas[i])
        ref.flush()
        for q in done[i::2]:
            assert np.allclose(q.result, r.result, atol=1e-6)


def test_jt_plans_live_in_shared_plan_cache():
    bn = _discrete_bn()
    names = [v.name for v in bn.order]
    cache = PlanCache()
    eng = PGMQueryEngine(bn, mode="exact", plan_cache=cache)
    eng.submit(names[-1], {names[0]: 1.0})
    eng.flush()
    keys = cache.keys()
    assert len(keys) == 1 and keys[0].mode == "jt-discrete"
    assert keys[0].network_version == 0
    # same schema + batch again: a cache hit, no new plan
    eng.submit(names[-1], {names[0]: 0.0})
    eng.flush()
    assert len(cache) == 1 and cache.stats()["hits"] >= 1


def test_set_model_bumps_version_and_old_plans_stop_hitting():
    bn, bn2 = _discrete_bn(0), _discrete_bn(9)
    names = [v.name for v in bn.order]
    eng = PGMQueryEngine(bn, mode="exact")
    q0 = eng.submit(names[-1], {names[0]: 1.0})
    eng.flush()
    eng.set_model(bn2)
    assert eng.network_version == 1
    q1 = eng.submit(names[-1], {names[0]: 1.0})
    eng.flush()
    assert not np.allclose(q0.result, q1.result)    # new CPDs actually serve
    versions = {k.network_version for k in eng.plans.keys()}
    assert versions == {0, 1}                       # old plan aged, not reused


def test_exact_pad_pow2_matches_unpadded():
    bn = _discrete_bn()
    names = [v.name for v in bn.order]
    ev = [{names[0]: float(i % 2), names[1]: float((i // 2) % 2)}
          for i in range(5)]
    plain = PGMQueryEngine(bn, mode="exact")
    padded = PGMQueryEngine(bn, mode="exact", pad_pow2=True)
    for e in ev:
        plain.submit(names[-1], e)
        padded.submit(names[-1], e)
    a, b = plain.flush(), padded.flush()
    for qa, qb in zip(a, b):
        assert np.allclose(qa.result, qb.result, atol=1e-6)
        assert np.isclose(qa.log_evidence, qb.log_evidence, atol=1e-6)
    # the padded engine compiled for the pow2 capacity
    assert {k.batch_shape[0] for k in padded.plans.keys()} == {8}


def test_deprecated_cache_shims_warn_and_reflect_plans():
    bn = _discrete_bn()
    names = [v.name for v in bn.order]
    eng = PGMQueryEngine(bn, mode="exact")
    eng.submit(names[-1], {names[0]: 1.0})
    eng.flush()
    with pytest.warns(DeprecationWarning):
        compiled = eng._jt._compiled
    assert len(compiled) == 1
    ((schema, batch, dtypes),) = compiled.keys()
    assert schema == (names[0],) and batch == 1
    with pytest.warns(DeprecationWarning):
        assert eng._vmp_caps == set()
    with pytest.warns(DeprecationWarning):
        assert eng._temporal_keys == set()


# ---------------------------------------------------------------------------
# AsyncPGMServer: micro-batching, deadlines, hot swap
# ---------------------------------------------------------------------------


def _direct_answers(bn, queries, **engine_kw):
    eng = PGMQueryEngine(bn, mode="exact", **engine_kw)
    qs = [eng.submit(t, e) for t, e in queries]
    eng.flush()
    return [q.result for q in qs]


def test_async_size_trigger_matches_direct_engine():
    """A size-triggered micro-batch must be bit-identical to the direct
    engine on the same queries (same bucket, same pow2 padding)."""
    bn = _discrete_bn()
    names = [v.name for v in bn.order]
    queries = [(names[-1], {names[0]: float(i % 2)}) for i in range(4)]
    with AsyncPGMServer(bn, mode="exact", max_batch=4,
                        max_delay_ms=10_000, default_deadline_ms=60_000,
                        deadline_margin_ms=0.0) as srv:
        tickets = [srv.submit(t, e) for t, e in queries]
        results = [t.result(timeout=120) for t in tickets]
        assert all(t.trigger == "size" for t in tickets)
    direct = _direct_answers(bn, queries, pad_pow2=True)
    for r, d in zip(results, direct):
        assert np.array_equal(r, d)


def test_async_timeout_trigger_matches_direct_engine():
    bn = _discrete_bn()
    names = [v.name for v in bn.order]
    queries = [(names[-1], {names[1]: 1.0}), (names[-1], {names[1]: 0.0})]
    with AsyncPGMServer(bn, mode="exact", max_batch=64, max_delay_ms=50,
                        default_deadline_ms=60_000) as srv:
        tickets = [srv.submit(t, e) for t, e in queries]
        results = [t.result(timeout=120) for t in tickets]
        assert all(t.trigger == "timeout" for t in tickets)
    direct = _direct_answers(bn, queries, pad_pow2=True)
    for r, d in zip(results, direct):
        assert np.array_equal(r, d)


def test_deadline_drives_flush_order_across_mixed_schemas():
    bn = _discrete_bn()
    names = [v.name for v in bn.order]
    slow = (names[-1], {names[0]: 1.0})
    fast = (names[-1], {names[1]: 1.0, names[2]: 0.0})
    with AsyncPGMServer(bn, mode="exact", max_batch=64,
                        max_delay_ms=10_000, default_deadline_ms=60_000,
                        deadline_margin_ms=100.0) as srv:
        # warm both plans so flush order is not compile-order noise
        for t, e in (slow, fast):
            srv.submit(t, e, deadline_ms=1.0).result(timeout=120)
        t_slow = srv.submit(*slow, deadline_ms=2_000)   # submitted FIRST
        t_fast = srv.submit(*fast, deadline_ms=500)     # tighter deadline
        t_fast.result(timeout=120)
        t_slow.result(timeout=120)
        assert t_fast.trigger == "deadline"
        assert t_fast.done_s < t_slow.done_s    # deadline order, not FIFO
    assert t_fast.deadline_miss is False        # margin held: flushed early


def test_hot_swap_mid_stream_drops_nothing_and_changes_answers():
    bn, bn2 = _discrete_bn(0), _discrete_bn(9)
    names = [v.name for v in bn.order]
    query = (names[-1], {names[0]: 1.0})
    with AsyncPGMServer(bn, mode="exact", max_batch=8, max_delay_ms=5,
                        default_deadline_ms=60_000) as srv:
        srv.submit(*query).result(timeout=120)      # warm v0
        tickets, stop = [], threading.Event()

        def pump():
            while not stop.is_set():
                tickets.append(srv.submit(*query))
                time.sleep(0.002)

        th = threading.Thread(target=pump)
        th.start()
        try:
            time.sleep(0.05)
            info = srv.swap_model(bn2)
            time.sleep(0.05)
        finally:
            stop.set()
            th.join()
        results = [t.result(timeout=120) for t in tickets]
        assert srv.stats()["pending"] == 0          # zero dropped requests
        assert info["new_version"] == 1 and info["warmed_plans"] >= 1
    assert all(t.error is None for t in tickets)
    old = _direct_answers(bn, [query], pad_pow2=True)[0]
    new = _direct_answers(bn2, [query], pad_pow2=True)[0]
    assert not np.allclose(old, new)                # swap is observable
    for r in results:                               # every answer is one of
        assert np.allclose(r, old) or np.allclose(r, new)
    assert any(np.allclose(r, new) for r in results)
    # old-version plans were invalidated by the drain
    assert all(k.network_version == 1 for k in srv.plans.keys())


def test_async_vmp_replicas_match_single_worker():
    stream, _, _ = syn.gmm_stream(400, 3, 4, seed=1)
    from repro.pgm_models import GaussianMixture

    m = GaussianMixture(stream.attributes, n_states=3)
    m.update_model(stream)
    xs = np.asarray(stream.collect().xc)
    queries = [("Z", {f"X{i}": float(xs[j, i]) for i in range(4)})
               for j in range(12)]

    def run(replicas):
        with AsyncPGMServer(m, mode="vmp", max_batch=4, max_delay_ms=20,
                            default_deadline_ms=60_000,
                            replicas=replicas) as srv:
            tickets = [srv.submit(t, e) for t, e in queries]
            return [t.result(timeout=120) for t in tickets]

    one, three = run(1), run(3)
    for a, b in zip(one, three):
        assert np.allclose(a, b, atol=1e-6)


def test_mesh_replica_parity_with_single_device():
    """dvmp_posterior_z row-parity with single-device posterior_z, on a
    forced multi-device host (subprocess, like tests/test_distributed)."""
    from test_distributed import run_with_devices

    out = run_with_devices("""
        import numpy as np
        from repro.data import synthetic as syn
        from repro.pgm_models import GaussianMixture
        from repro.serve.engine import PGMQueryEngine
        from repro.core.compat import make_mesh

        stream, _, _ = syn.gmm_stream(256, 3, 4, seed=1)
        m = GaussianMixture(stream.attributes, n_states=3)
        m.update_model(stream)
        xs = np.asarray(stream.collect().xc)
        mesh = make_mesh((4,), ("data",))
        single = PGMQueryEngine(m, mode="vmp")
        sharded = PGMQueryEngine(m, mode="vmp", mesh=mesh)
        for eng in (single, sharded):
            for j in range(10):
                eng.submit("Z", {f"X{i}": float(xs[j, i]) for i in range(4)})
        a, b = single.flush(), sharded.flush()
        for qa, qb in zip(a, b):
            assert np.allclose(qa.result, qb.result, atol=1e-5), (qa.qid)
        assert any(k.mode == "vmp" for k in sharded.plans.keys())
        print("MESH_SERVE_OK")
    """, n=4)
    assert "MESH_SERVE_OK" in out
