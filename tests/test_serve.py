"""Serving engine: request lifecycle, continuous batching."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.nn import transformer as T
from repro.serve.engine import DecodeEngine, Request


def _engine(arch="granite-3-2b", batch=2, capacity=64):
    cfg = get_config(arch).reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    return DecodeEngine(params, cfg, batch, capacity), cfg


def test_engine_drains_all_requests():
    eng, cfg = _engine()
    for rid in range(5):
        eng.submit(Request(rid=rid, prompt=[1, 2, 3], max_new=6))
    reqs = list(eng.queue)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 6 for r in reqs)
    assert all(0 <= t < cfg.vocab for r in reqs for t in r.out)


def test_engine_continuous_batching_reuses_slots():
    eng, _ = _engine(batch=2)
    reqs = [Request(rid=i, prompt=[i + 1], max_new=3) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)   # 6 requests through 2 slots


def test_greedy_engine_matches_direct_decode():
    """A single request in slot 0 must reproduce plain greedy decoding."""
    cfg = get_config("granite-3-2b").reduced()
    params = T.init_model(jax.random.PRNGKey(0), cfg)
    prompt, n_new = [5, 9, 2], 5
    # direct
    state = T.init_decode_state(params, cfg, 1, 64)
    toks = []
    cur = jnp.asarray([[prompt[0]]], jnp.int32)
    pending = prompt[1:]
    for _ in range(len(prompt) + n_new - 1):
        logits, state = T.decode_step(params, state, cur, cfg)
        if pending:
            cur = jnp.asarray([[pending.pop(0)]], jnp.int32)
        else:
            nxt = int(logits[0, 0].argmax())
            toks.append(nxt)
            cur = jnp.asarray([[nxt]], jnp.int32)
            if len(toks) == n_new:
                break
    # engine (batch=1)
    eng = DecodeEngine(params, cfg, 1, 64)
    req = Request(rid=0, prompt=list(prompt), max_new=n_new)
    eng.submit(req)
    eng.run()
    assert req.out == toks, (req.out, toks)
