"""Temporal hot path (fused scanned VB-EM, masks, streaming, serving).

Covers the fused/unfused parity contract for every dynamic model class,
the masked forward-backward padding semantics (left padding seeds from
the initial distribution; NaN padding is never read), factorial-HMM
structured VB against exact joint-chain inference, SLDS regime
segmentation, sequence-batch streaming with drift detection, the
compiled-program cache (no retrace across same-shape refits), and
temporal serving through ``PGMQueryEngine(mode="temporal")``.
"""

import contextlib
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.factored_frontier import (Factorial2TBN,
                                          factored_frontier_filter,
                                          factored_frontier_smooth)
from repro.data import synthetic as syn
from repro.obs import sink as obs
from repro.pgm_models import (AutoRegressiveHMM, FactorialHMMModel,
                              HiddenMarkovModel, KalmanFilter, SwitchingLDS,
                              seq_stream_fit)
from repro.pgm_models import dynamic as dyn
from repro.serve.engine import PGMQueryEngine


@contextlib.contextmanager
def _obs_to(tmp_path, level="basic"):
    path = str(tmp_path / "events.jsonl")
    prev = obs.configure(level=level, path=path, reset_counters=True)
    try:
        yield path
    finally:
        obs.configure(level=prev["level"], path=prev["path"],
                      reset_counters=True)


# ---------------------------------------------------------------------------
# masked forward-backward
# ---------------------------------------------------------------------------


def test_forward_backward_left_padding():
    """A left-padded sequence must behave exactly like its observed suffix:
    the recursion seeds from log_init at the first OBSERVED step (no
    spurious transition out of the padding) and the padded frames' loglik
    values — here NaN — are never read."""
    rng = np.random.default_rng(0)
    S, T, P = 3, 9, 3
    log_init = jnp.log(jnp.asarray([0.6, 0.3, 0.1], jnp.float32))
    tr = (0.2 * rng.dirichlet(np.ones(S), size=S)
          + 0.8 * np.eye(S)).astype(np.float32)
    log_trans = jnp.log(jnp.asarray(tr))
    ll_obs = jnp.asarray(rng.standard_normal((T - P, S)), jnp.float32)
    ll_pad = jnp.concatenate([jnp.full((P, S), jnp.nan), ll_obs])
    mask = jnp.concatenate([jnp.zeros(P), jnp.ones(T - P)])

    g_pad, xi_pad, lz_pad = dyn.forward_backward(
        log_init, log_trans, ll_pad, mask)
    g_ref, xi_ref, lz_ref = dyn.forward_backward(
        log_init, log_trans, ll_obs, jnp.ones(T - P))

    assert np.isfinite(np.asarray(g_pad)).all()
    np.testing.assert_allclose(float(lz_pad), float(lz_ref), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(g_pad[P:]), np.asarray(g_ref),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(xi_pad), np.asarray(xi_ref),
                               atol=1e-5)
    assert float(np.abs(np.asarray(g_pad[:P])).sum()) == 0.0


def test_forward_backward_fully_masked():
    S, T = 2, 5
    li = jnp.log(jnp.full((S,), 0.5))
    lt = jnp.log(jnp.full((S, S), 0.5))
    g, xi, lz = dyn.forward_backward(
        li, lt, jnp.full((T, S), jnp.nan), jnp.zeros(T))
    assert float(lz) == 0.0
    assert float(np.abs(np.asarray(g)).sum()) == 0.0
    assert float(np.abs(np.asarray(xi)).sum()) == 0.0


def test_factored_frontier_mask():
    """Masked steps hold the belief and contribute 0 to the loglik bound;
    the padded loglik values (NaN here) are never read."""
    rng = np.random.default_rng(1)
    T, C, S = 7, 2, 3
    init = jnp.asarray(rng.dirichlet(np.ones(S), size=C), jnp.float32)
    trans = jnp.asarray(rng.dirichlet(np.ones(S), size=(C, S)), jnp.float32)
    model = Factorial2TBN(init=init, trans=trans)
    ll = rng.standard_normal((T, C, S)).astype(np.float32)
    ll[3] = np.nan
    mask = np.ones(T, np.float32)
    mask[3] = 0.0
    beliefs, lls = factored_frontier_filter(
        model, jnp.asarray(ll), jnp.asarray(mask))
    assert np.isfinite(np.asarray(beliefs)).all()
    np.testing.assert_allclose(np.asarray(beliefs[3]), np.asarray(beliefs[2]),
                               atol=1e-6)
    assert float(lls[3]) == 0.0
    gam = factored_frontier_smooth(model, jnp.asarray(ll), jnp.asarray(mask))
    assert np.isfinite(np.asarray(gam)).all()
    # no-mask call == explicit all-ones mask (backward compatibility)
    ll_ok = jnp.asarray(rng.standard_normal((T, C, S)), jnp.float32)
    b1, l1 = factored_frontier_filter(model, ll_ok)
    b2, l2 = factored_frontier_filter(model, ll_ok, jnp.ones(T))
    np.testing.assert_allclose(np.asarray(b1), np.asarray(b2), atol=1e-6)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-6)


# ---------------------------------------------------------------------------
# fused-vs-unfused parity (one per dynamic model class)
# ---------------------------------------------------------------------------


def test_hmm_fused_unfused_parity():
    stream = syn.hmm_sequences(s=16, t=12, states=2, f=2, seed=3)[0]
    m1 = HiddenMarkovModel(stream.attributes, n_states=2, seed=0)
    m2 = HiddenMarkovModel(stream.attributes, n_states=2, seed=0)
    e1 = m1.update_model(stream, sweeps=8, tol=0.0, fused=True)
    e2 = m2.update_model(stream, sweeps=8, tol=0.0, fused=False)
    np.testing.assert_allclose(e1, e2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(m1.posterior.emis.m),
                               np.asarray(m2.posterior.emis.m), atol=1e-3)
    np.testing.assert_allclose(np.asarray(m1.posterior.trans.alpha),
                               np.asarray(m2.posterior.trans.alpha),
                               rtol=1e-3)
    # metrics pytree reports every sweep active at tol=0
    assert int(np.asarray(m1.fit_metrics.active).sum()) == 8


def test_arhmm_fused_unfused_parity():
    stream = syn.hmm_sequences(s=12, t=10, states=2, f=2, seed=4)[0]
    m1 = AutoRegressiveHMM(stream.attributes, n_states=2, seed=0)
    m2 = AutoRegressiveHMM(stream.attributes, n_states=2, seed=0)
    e1 = m1.update_model(stream, sweeps=5, tol=0.0, fused=True)
    e2 = m2.update_model(stream, sweeps=5, tol=0.0, fused=False)
    np.testing.assert_allclose(e1, e2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(m1.posterior.emis.m),
                               np.asarray(m2.posterior.emis.m), atol=1e-3)


def test_fhmm_fused_unfused_parity():
    stream = syn.hmm_sequences(s=12, t=10, states=2, f=3, seed=5)[0]
    m1 = FactorialHMMModel(stream.attributes, n_chains=2, n_states=2, seed=0)
    m2 = FactorialHMMModel(stream.attributes, n_chains=2, n_states=2, seed=0)
    e1 = m1.update_model(stream, sweeps=6, tol=0.0, fused=True)
    e2 = m2.update_model(stream, sweeps=6, tol=0.0, fused=False)
    np.testing.assert_allclose(e1, e2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(m1.means), np.asarray(m2.means),
                               atol=1e-3)


def test_kalman_fused_unfused_parity():
    stream = syn.lds_sequences(s=12, t=15, dim_h=2, f=3, seed=6)[0]
    m1 = KalmanFilter(stream.attributes, n_hidden=2, seed=0)
    m2 = KalmanFilter(stream.attributes, n_hidden=2, seed=0)
    e1 = m1.update_model(stream, sweeps=6, tol=0.0, fused=True)
    e2 = m2.update_model(stream, sweeps=6, tol=0.0, fused=False)
    np.testing.assert_allclose(e1, e2, rtol=1e-4)
    for a, b in ((m1.A, m2.A), (m1.C, m2.C), (m1.q, m2.q), (m1.r, m2.r)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-3)


def test_slds_fused_unfused_parity():
    stream = syn.slds_stream(1, s=12, t=16, dim_h=2, f=3, seed=7)[0][0]
    m1 = SwitchingLDS(stream.attributes, n_states=2, n_hidden=2, seed=0)
    m2 = SwitchingLDS(stream.attributes, n_states=2, n_hidden=2, seed=0)
    e1 = m1.update_model(stream, sweeps=4, tol=0.0, fused=True)
    e2 = m2.update_model(stream, sweeps=4, tol=0.0, fused=False)
    np.testing.assert_allclose(e1, e2, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(m1.A), np.asarray(m2.A), atol=2e-3)
    np.testing.assert_allclose(np.asarray(m1.resp), np.asarray(m2.resp),
                               atol=1e-3)


# ---------------------------------------------------------------------------
# factorial HMM structured VB vs exact joint-chain inference
# ---------------------------------------------------------------------------


def test_fhmm_estep_matches_exact_joint():
    """C=2 chains, S=2 states: run ONLY the structured mean-field E-step
    (fixed parameters, iterated Jacobi sweeps) and compare the per-chain
    marginals against EXACT forward-backward on the equivalent joint HMM
    (S^C = 4 states, additive means).  With well-separated contributions
    the factored posterior must recover the exact marginals."""
    rng = np.random.default_rng(8)
    B, T, F, C, S = 6, 14, 3, 2, 2
    means = jnp.asarray(
        np.stack([
            np.stack([np.full(F, -3.0), np.full(F, 3.0)]),     # chain 0
            np.stack([np.full(F, -1.0), np.full(F, 1.0)]),     # chain 1
        ]), jnp.float32)                                       # [C, S, F]
    trans = np.stack([0.1 + 0.8 * np.eye(S)] * C).astype(np.float32)
    log_trans = jnp.log(jnp.asarray(trans))
    log_init = jnp.log(jnp.full((C, S), 0.5))
    noise = jnp.asarray(0.25)

    # sample from the true generative model
    xs = np.zeros((B, T, F), np.float32)
    for b in range(B):
        z = rng.integers(0, S, C)
        for t in range(T):
            for c in range(C):
                z[c] = rng.choice(S, p=trans[c, z[c]])
            mu = np.asarray(means)[np.arange(C), z].sum(0)
            xs[b, t] = mu + np.sqrt(0.25) * rng.standard_normal(F)
    xc = jnp.asarray(xs)
    mask = jnp.ones((B, T))

    # structured VB E-step only: iterate _fhmm_sweep with FIXED params
    gammas = jnp.full((B, T, C, S), 1.0 / S)
    for _ in range(25):
        _, _, gammas, _ = dyn._fhmm_sweep(
            means, log_trans, log_init, noise, gammas, xc, mask, "einsum")

    # exact joint oracle: 4-state HMM, joint transition = kron of chains
    joint_means = (means[0][:, None, :] + means[1][None, :, :]
                   ).reshape(S * S, F)                          # [4, F]
    joint_trans = jnp.asarray(np.kron(trans[0], trans[1]))
    joint_init = jnp.full((S * S,), 1.0 / (S * S))
    diff = xc[:, :, None, :] - joint_means[None, None]
    ll = (-(0.5 / float(noise)) * (diff ** 2).sum(-1)
          - 0.5 * F * np.log(2 * np.pi * float(noise)))         # [B,T,4]
    g_joint = jnp.stack([
        dyn.forward_backward(jnp.log(joint_init), jnp.log(joint_trans),
                             ll[b], mask[b])[0]
        for b in range(B)])                                     # [B,T,4]
    g_joint = g_joint.reshape(B, T, S, S)
    marg0 = np.asarray(g_joint.sum(-1))                         # chain 0
    marg1 = np.asarray(g_joint.sum(-2))                         # chain 1

    g = np.asarray(gammas)
    assert (g[:, :, 0].argmax(-1) == marg0.argmax(-1)).mean() > 0.95
    assert (g[:, :, 1].argmax(-1) == marg1.argmax(-1)).mean() > 0.9
    assert np.abs(g[:, :, 0] - marg0).max() < 0.15


# ---------------------------------------------------------------------------
# SLDS regime segmentation
# ---------------------------------------------------------------------------


def test_slds_two_regime_segmentation():
    """Sequences switch dynamics (rotation -> reverse rotation) at the
    midpoint; the learnt switch responsibilities must segment the two
    halves (up to label permutation)."""
    stream = syn.slds_stream(1, s=24, t=40, dim_h=2, f=4, seed=9)[0][0]
    m = SwitchingLDS(stream.attributes, n_states=2, n_hidden=2, seed=0)
    m.update_model(stream, sweeps=12, tol=0.0)
    dec = np.asarray(m.resp).argmax(-1)                 # [B, T]
    T = dec.shape[1]
    true = (np.arange(T) >= T // 2).astype(int)[None].repeat(dec.shape[0], 0)
    # skip the first steps of each half (filter burn-in after the switch)
    keep = np.ones(T, bool)
    keep[:4] = False
    keep[T // 2: T // 2 + 4] = False
    agree = (dec[:, keep] == true[:, keep]).mean()
    assert max(agree, 1.0 - agree) > 0.75


# ---------------------------------------------------------------------------
# streaming (Eq. 3) with drift detection
# ---------------------------------------------------------------------------


def test_seq_stream_fit_detects_regime_switch(tmp_path):
    batches, attrs, switch_at = syn.hmm_stream(
        n_batches=6, s=24, t=16, states=2, f=2, shift=8.0, seed=10)
    m = HiddenMarkovModel(attrs, n_states=2, seed=0)
    with _obs_to(tmp_path) as path:
        info = seq_stream_fit(m, batches, sweeps=6, tol=0.0,
                              drift_threshold=5.0)
        counts = obs.validate_obs_events(path)
    drifted = np.asarray(info["drifted"]).astype(bool)
    assert m.n_drifts >= 1
    assert drifted.any()
    # the first firing must be at or after the regime switch
    assert int(np.argmax(drifted)) >= switch_at
    assert not drifted[:switch_at].any()
    assert counts.get("stream_batch", 0) == len(batches)
    assert counts.get("drift", 0) == int(drifted.sum())
    # the refit recovers: posterior means live near the shifted regime
    sm = np.sort(m.state_means()[:, 0])
    assert sm.max() > 6.0


# ---------------------------------------------------------------------------
# compiled-program cache: same shapes => no retrace
# ---------------------------------------------------------------------------


def test_update_model_does_not_retrace_same_shapes():
    stream = syn.hmm_sequences(s=8, t=10, states=2, f=2, seed=11)[0]
    m1 = HiddenMarkovModel(stream.attributes, n_states=2, seed=0)
    m1.update_model(stream, sweeps=3, tol=0.0)
    before = dyn.trace_counts().get("hmm_fit", 0)
    assert before >= 1
    # second fit on the SAME model (Bayesian update) and a FRESH model of
    # identical shape both reuse the compiled program
    m1.update_model(stream, sweeps=3, tol=0.0)
    m2 = HiddenMarkovModel(stream.attributes, n_states=2, seed=1)
    m2.update_model(stream, sweeps=3, tol=0.0)
    assert dyn.trace_counts().get("hmm_fit", 0) == before
    # a different shape DOES compile a new program (the cache key works)
    stream2 = syn.hmm_sequences(s=8, t=11, states=2, f=2, seed=11)[0]
    m3 = HiddenMarkovModel(stream2.attributes, n_states=2, seed=0)
    m3.update_model(stream2, sweeps=3, tol=0.0)
    assert dyn.trace_counts().get("hmm_fit", 0) == before + 1


# ---------------------------------------------------------------------------
# temporal serving through the query engine
# ---------------------------------------------------------------------------


def test_temporal_query_engine(tmp_path):
    stream = syn.hmm_sequences(s=16, t=12, states=3, f=2, seed=12)[0]
    m = HiddenMarkovModel(stream.attributes, n_states=3, seed=0)
    m.update_model(stream, sweeps=5)
    xc = np.asarray(stream.xc)

    with _obs_to(tmp_path) as path:
        eng = PGMQueryEngine(m, mode="temporal")
        qf = [eng.submit("filter", {}, payload=xc[i]) for i in range(3)]
        qp = eng.submit("predict", {"horizon": 4}, payload=xc[3])
        eng.flush()
        # same (T, horizon, cap) bucket again => compiled-program cache hit
        q2 = [eng.submit("filter", {}, payload=xc[i]) for i in range(4, 7)]
        eng.flush()
        counts = obs.validate_obs_events(path)
        events = [json.loads(l) for l in open(path)]

    for q in qf + q2:
        r = np.asarray(q.result)
        assert r.shape == (12, 3)
        np.testing.assert_allclose(r.sum(-1), 1.0, atol=1e-4)
    rp = np.asarray(qp.result)
    assert rp.shape == (3,)
    np.testing.assert_allclose(rp.sum(), 1.0, atol=1e-4)
    # parity with the model's own filtering API
    ref = np.asarray(m.filtered_posterior(jnp.asarray(xc[:3])))
    np.testing.assert_allclose(np.asarray(qf[0].result), ref[0], atol=1e-5)

    assert counts.get("temporal_plan", 0) == 2      # (T,0) and (T,4) buckets
    buckets = [e for e in events if e["event"] == "serve_bucket"]
    hits = [e["cache_hit"] for e in buckets]
    assert hits.count(True) == 1                    # the repeated filter bucket

    # invalid submissions are rejected up front
    with pytest.raises(ValueError):
        eng.submit("filter", {})                    # no payload
    with pytest.raises(ValueError):
        eng.submit("marginal", {}, payload=xc[0])   # unknown target


def test_temporal_engine_requires_temporal_model():
    stream = syn.lds_sequences(s=4, t=6, dim_h=2, f=2, seed=13)[0]
    kf = KalmanFilter(stream.attributes, n_hidden=2)
    with pytest.raises(ValueError):
        PGMQueryEngine(kf, mode="temporal")
