"""infer_exact: junction tree vs brute force, HMM oracle, CLG conditioning."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dag import (BayesianNetwork, CLGCPD, DAG, MultinomialCPD,
                            Variables)
from repro.core.factored_frontier import hmm_forward
from repro.infer_exact import (JunctionTreeEngine, brute_posterior,
                               compile_junction_tree)
from repro.infer_exact.graph import verify_running_intersection


def random_discrete_bn(seed: int, n: int = 6, p_edge: float = 0.45):
    rng = np.random.RandomState(seed)
    vs = Variables()
    cards = rng.randint(2, 4, n)
    xs = [vs.new_multinomial(f"V{i}", int(cards[i])) for i in range(n)]
    dag = DAG(vs)
    cpds = {}
    for i, v in enumerate(xs):
        pa = [xs[j] for j in range(i) if rng.rand() < p_edge]
        for p in pa:
            dag.add_parent(v, p)
        shape = tuple(p.card for p in pa) + (v.card,)
        t = rng.dirichlet(np.ones(v.card),
                          size=shape[:-1] or (1,)).reshape(shape)
        cpds[v.name] = MultinomialCPD(jnp.asarray(t))
    return BayesianNetwork(dag, cpds), xs


@pytest.fixture(scope="module")
def clg_net():
    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    X1 = vs.new_gaussian("X1")
    X2 = vs.new_gaussian("X2")
    dag = DAG(vs)
    dag.add_parent(X1, Z)
    dag.add_parent(X2, Z)
    cpds = {
        "Z": MultinomialCPD(jnp.array([0.3, 0.7])),
        "X1": CLGCPD(alpha=jnp.array([0.0, 4.0]), beta=jnp.zeros((2, 0)),
                     sigma2=jnp.array([1.0, 1.0])),
        "X2": CLGCPD(alpha=jnp.array([-2.0, 2.0]), beta=jnp.zeros((2, 0)),
                     sigma2=jnp.array([0.5, 2.0])),
    }
    return BayesianNetwork(dag, cpds), Z, X1, X2


# -- acceptance criterion: marginals match brute force on random nets --------


@pytest.mark.parametrize("seed", [0, 1, 2, 3, 7])
def test_jt_matches_brute_force(seed):
    bn, xs = random_discrete_bn(seed)
    for evidence in ({}, {"V1": 1, "V4": 0}):
        eng = JunctionTreeEngine(bn)
        eng.set_evidence(evidence)
        eng.run_inference()
        for v in xs:
            if v.name in evidence:
                continue
            got = np.asarray(eng.posterior_discrete(v))
            exp = np.asarray(brute_posterior(bn, v, evidence))
            np.testing.assert_allclose(got, exp, atol=1e-5)


def test_jt_log_evidence_matches_brute_force():
    from repro.infer_exact.brute import brute_log_evidence

    bn, xs = random_discrete_bn(2)
    ev = {"V0": 1, "V5": 0}
    eng = JunctionTreeEngine(bn)
    eng.set_evidence(ev)
    eng.run_inference()
    np.testing.assert_allclose(float(eng.log_evidence()),
                               float(brute_log_evidence(bn, ev)), atol=1e-5)


# -- chain models: the factored-frontier C=1 exact-HMM oracle ----------------


def test_jt_matches_hmm_forward_on_chain():
    T, S, V = 6, 3, 4
    rng = np.random.RandomState(3)
    init = rng.dirichlet(np.ones(S))
    trans = rng.dirichlet(np.ones(S), size=S)        # [S, S]
    emit = rng.dirichlet(np.ones(V), size=S)         # [S, V]
    obs = rng.randint(0, V, T)

    vs = Variables()
    hs = [vs.new_multinomial(f"H{t}", S) for t in range(T)]
    os_ = [vs.new_multinomial(f"O{t}", V) for t in range(T)]
    dag = DAG(vs)
    cpds = {"H0": MultinomialCPD(jnp.asarray(init))}
    for t in range(1, T):
        dag.add_parent(hs[t], hs[t - 1])
        cpds[f"H{t}"] = MultinomialCPD(jnp.asarray(trans))
    for t in range(T):
        dag.add_parent(os_[t], hs[t])
        cpds[f"O{t}"] = MultinomialCPD(jnp.asarray(emit))
    bn = BayesianNetwork(dag, cpds)

    evidence = {f"O{t}": int(obs[t]) for t in range(T)}
    eng = JunctionTreeEngine(bn)
    eng.set_evidence(evidence)
    eng.run_inference()
    got = np.asarray(eng.posterior_discrete(hs[-1]))

    # exact reference: float64 forward recursion
    a = init * emit[:, obs[0]]
    a = a / a.sum()
    for t in range(1, T):
        a = (a @ trans) * emit[:, obs[t]]
        a = a / a.sum()
    np.testing.assert_allclose(got, a, atol=1e-5)

    # the in-repo C=1 factored-frontier oracle (float32 scan) agrees too
    loglik = jnp.log(jnp.asarray(emit[:, obs].T))    # [T, S]
    beliefs, _ = hmm_forward(jnp.asarray(init), jnp.asarray(trans), loglik)
    # filtered == smoothed at the final step == JT marginal of H_{T-1}
    np.testing.assert_allclose(got, np.asarray(beliefs[-1]), atol=1e-3)


# -- CLG conditioning ---------------------------------------------------------


def test_jt_clg_closed_form(clg_net):
    bn, Z, X1, X2 = clg_net
    eng = JunctionTreeEngine(bn)
    eng.set_evidence({"X1": 3.0, "X2": 1.0})
    eng.run_inference()

    def npdf(x, m, s2=1.0):
        return np.exp(-0.5 * (x - m) ** 2 / s2) / np.sqrt(2 * np.pi * s2)

    l0 = 0.3 * npdf(3, 0) * npdf(1, -2, 0.5)
    l1 = 0.7 * npdf(3, 4) * npdf(1, 2, 2.0)
    exact = np.array([l0, l1]) / (l0 + l1)
    np.testing.assert_allclose(np.asarray(eng.posterior_discrete(Z)), exact,
                               atol=1e-6)
    np.testing.assert_allclose(float(eng.log_evidence()), np.log(l0 + l1),
                               atol=1e-5)


def test_jt_continuous_posterior_mixture(clg_net):
    bn, Z, X1, X2 = clg_net
    eng = JunctionTreeEngine(bn)
    eng.set_evidence({"X1": 3.0})
    eng.run_inference()
    m, v = eng.posterior_mean_var(X2)

    def npdf(x, mu):
        return np.exp(-0.5 * (x - mu) ** 2) / np.sqrt(2 * np.pi)

    w = np.array([0.3 * npdf(3, 0), 0.7 * npdf(3, 4)])
    w = w / w.sum()
    mu = np.array([-2.0, 2.0])
    s2 = np.array([0.5, 2.0])
    em = (w * mu).sum()
    ev = (w * (s2 + mu ** 2)).sum() - em ** 2
    np.testing.assert_allclose(float(m), em, atol=1e-5)
    np.testing.assert_allclose(float(v), ev, atol=1e-5)


def test_jt_regression_parent_conditioning():
    """Observed continuous parent feeds the child's lambda analytically."""
    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    X1 = vs.new_gaussian("X1")
    X2 = vs.new_gaussian("X2")
    dag = DAG(vs)
    dag.add_parent(X1, Z)
    dag.add_parent(X2, Z)
    dag.add_parent(X2, X1)
    bn = BayesianNetwork(dag, {
        "Z": MultinomialCPD(jnp.array([0.4, 0.6])),
        "X1": CLGCPD(jnp.array([0.0, 4.0]), jnp.zeros((2, 0)),
                     jnp.array([1.0, 1.0])),
        "X2": CLGCPD(jnp.array([1.0, -1.0]), jnp.array([[0.5], [2.0]]),
                     jnp.array([1.0, 1.0]))})
    eng = JunctionTreeEngine(bn)
    eng.set_evidence({"X1": 2.0, "X2": 1.5})
    eng.run_inference()

    def npdf(x, m):
        return np.exp(-0.5 * (x - m) ** 2) / np.sqrt(2 * np.pi)

    l0 = 0.4 * npdf(2, 0) * npdf(1.5, 2.0)
    l1 = 0.6 * npdf(2, 4) * npdf(1.5, 3.0)
    exact = np.array([l0, l1]) / (l0 + l1)
    np.testing.assert_allclose(np.asarray(eng.posterior_discrete(Z)), exact,
                               atol=1e-6)
    # unobserved continuous parent of an observed node: the strong junction
    # tree integrates X1 out exactly (this used to raise NotImplementedError)
    eng2 = JunctionTreeEngine(bn)
    eng2.set_evidence({"X2": 1.5})
    eng2.run_inference()
    np.testing.assert_allclose(
        np.asarray(eng2.posterior_discrete(Z)),
        np.asarray(brute_posterior(bn, Z, {"X2": 1.5})), atol=1e-5)
    m, v = eng2.posterior_mean_var(X1)
    from repro.infer_exact import brute_posterior_mean_var

    mb, vb = brute_posterior_mean_var(bn, X1, {"X2": 1.5})
    np.testing.assert_allclose(float(m), float(mb), atol=1e-5)
    np.testing.assert_allclose(float(v), float(vb), atol=1e-5)


# -- batching: many evidence instances in one device call --------------------


def test_jt_batched_evidence_matches_per_instance():
    bn, xs = random_discrete_bn(1)
    vals = np.array([0, 1, 1, 0])
    eng = JunctionTreeEngine(bn)
    eng.set_evidence({"V2": vals})
    eng.run_inference()
    batch = np.asarray(eng.posterior_discrete(xs[0]))
    assert batch.shape[0] == 4
    for b, v in enumerate(vals):
        e = JunctionTreeEngine(bn)
        e.set_evidence({"V2": int(v)})
        e.run_inference()
        np.testing.assert_allclose(batch[b],
                                   np.asarray(e.posterior_discrete(xs[0])),
                                   atol=1e-6)


def test_jt_pallas_path_matches_jnp():
    bn, xs = random_discrete_bn(4)
    ev = {"V1": np.array([0, 1, 0]), "V3": np.array([1, 1, 0])}
    ref_eng = JunctionTreeEngine(bn, use_pallas=False)
    ref_eng.set_evidence(ev)
    ref_eng.run_inference()
    pal = JunctionTreeEngine(bn, use_pallas=True)
    pal.set_evidence(ev)
    pal.run_inference()
    for v in xs:
        np.testing.assert_allclose(np.asarray(pal.posterior_discrete(v)),
                                   np.asarray(ref_eng.posterior_discrete(v)),
                                   atol=1e-5)


# -- compilation structure ---------------------------------------------------


def test_junction_tree_structure_and_rip():
    bn, _ = random_discrete_bn(5, n=6, p_edge=0.6)
    jt = compile_junction_tree(bn)
    assert len(jt.edges) == len(jt.cliques) - 1          # a tree
    verify_running_intersection(jt.cliques, jt.edges)    # no raise
    names = {v.name for v in bn.order if v.is_discrete}
    assert set().union(*jt.cliques) == names             # covers all vars
    for (a, b), s in zip(jt.edges, jt.sepsets):
        assert s == jt.cliques[a] & jt.cliques[b]


def test_rip_checker_catches_violation():
    cliques = [frozenset("ab"), frozenset("bc"), frozenset("ad")]
    # 'a' appears in cliques 0 and 2, but the path 0-1-2 drops it at 1
    with pytest.raises(AssertionError):
        verify_running_intersection(cliques, [(0, 1), (1, 2)])


# -- model-layer wiring ------------------------------------------------------


def test_posterior_exact_matches_vmp_on_gmm():
    from repro.data.synthetic import gmm_stream
    from repro.pgm_models import GaussianMixture

    s, _, _ = gmm_stream(600, 3, 4, seed=1)
    m = GaussianMixture(s.attributes, n_states=3)
    m.update_model(s)
    batch = s.collect()
    rz = np.asarray(m.posterior_z(batch))
    re = np.asarray(m.posterior_exact(batch))
    assert re.shape == rz.shape
    np.testing.assert_allclose(re, rz, atol=1e-3)
    np.testing.assert_allclose(re.sum(-1), 1.0, atol=1e-5)


def test_pgm_query_engine_schema_batching(clg_net):
    from repro.serve.engine import PGMQueryEngine

    bn, Z, X1, X2 = clg_net
    eng = PGMQueryEngine(bn, mode="exact")
    q1 = eng.submit("Z", {"X1": 3.0, "X2": 1.0})
    q2 = eng.submit("Z", {"X1": -1.0, "X2": 0.0})
    q3 = eng.submit("Z", {"X1": 3.0})          # different schema
    done = eng.flush()
    assert len(done) == 3 and all(q.done for q in done)
    assert not eng._queue
    # row 1 of the batched group == a fresh single query
    single = JunctionTreeEngine(bn)
    single.set_evidence({"X1": -1.0, "X2": 0.0})
    single.run_inference()
    np.testing.assert_allclose(q2.result,
                               np.asarray(single.posterior_discrete(Z)),
                               atol=1e-6)
    assert q1.log_evidence is not None and q3.log_evidence is not None


def test_pgm_query_engine_vmp_mode():
    """mode='vmp' serves q(Z | x) from a fitted plate model through the
    jitted posterior_z path — one compiled dispatch per schema group."""
    from repro.data.synthetic import gmm_stream
    from repro.pgm_models import GaussianMixture
    from repro.serve.engine import PGMQueryEngine

    s, _, _ = gmm_stream(600, 3, 4, seed=1)
    m = GaussianMixture(s.attributes, n_states=3)
    m.update_model(s)
    batch = s.collect()
    eng = PGMQueryEngine(m, mode="vmp")
    qs = [eng.submit("Z", {f"X{i}": float(batch.xc[b, i]) for i in range(4)})
          for b in range(5)]
    done = eng.flush()
    assert len(done) == 5 and all(q.done for q in done)
    expect = np.asarray(m.posterior_z(batch))[:5]
    got = np.stack([q.result for q in qs])
    np.testing.assert_allclose(got, expect, atol=1e-5)

    # malformed queries are rejected at submit — the queue is untouched,
    # so a later flush() cannot drop valid queued work
    with pytest.raises(ValueError, match="missing"):
        eng.submit("Z", {"X0": 0.0})
    with pytest.raises(ValueError, match="latent Z"):
        eng.submit("X0", {f"X{i}": 0.0 for i in range(4)})
    assert not eng._queue


# -- DAG.add_parent hardening -------------------------------------------------


def test_dag_rejects_duplicate_edge():
    vs = Variables()
    a = vs.new_multinomial("A", 2)
    b = vs.new_multinomial("B", 2)
    dag = DAG(vs)
    dag.add_parent(b, a)
    with pytest.raises(ValueError, match="duplicate"):
        dag.add_parent(b, a)
    assert len(dag.get_parents(b)) == 1


def test_dag_rejects_cycle_and_stays_valid():
    vs = Variables()
    a = vs.new_multinomial("A", 2)
    b = vs.new_multinomial("B", 2)
    c = vs.new_multinomial("C", 2)
    dag = DAG(vs)
    dag.add_parent(b, a)
    dag.add_parent(c, b)
    with pytest.raises(ValueError, match="cycle"):
        dag.add_parent(a, c)
    # failed insert left the graph untouched and acyclic
    assert dag.get_parents(a) == []
    assert [v.name for v in dag.topological_order()] == ["A", "B", "C"]


def test_dag_self_loop():
    vs = Variables()
    a = vs.new_multinomial("A", 2)
    dag = DAG(vs)
    with pytest.raises(ValueError, match="self-loop"):
        dag.add_parent(a, a)


# -- evidence validation ------------------------------------------------------


def test_jt_rejects_bad_evidence(clg_net):
    bn, Z, X1, X2 = clg_net
    eng = JunctionTreeEngine(bn)
    with pytest.raises(ValueError, match="unknown evidence"):
        eng.set_evidence({"X9": 1.0})
    with pytest.raises(ValueError, match="outside"):
        eng.set_evidence({"Z": 7})
    eng.set_evidence({"X1": np.array([1.0, 2.0]),
                      "X2": np.array([0.0, 1.0, 2.0])})
    with pytest.raises(ValueError, match="batch lengths"):
        eng.run_inference()


def test_jt_impossible_evidence_flags_neg_inf():
    vs = Variables()
    a = vs.new_multinomial("A", 2)
    b = vs.new_multinomial("B", 2)
    dag = DAG(vs)
    dag.add_parent(b, a)
    bn = BayesianNetwork(dag, {
        "A": MultinomialCPD(jnp.array([1.0, 0.0])),
        "B": MultinomialCPD(jnp.array([[1.0, 0.0], [0.5, 0.5]]))})
    eng = JunctionTreeEngine(bn)
    eng.set_evidence({"B": 1})
    eng.run_inference()
    assert np.isneginf(float(eng.log_evidence()))


def test_jt_batched_continuous_query_no_discrete_parents():
    """posterior_mean_var under batched evidence when the queried node's
    only parent is continuous (regression: B was taken from a placeholder)."""
    vs = Variables()
    Z = vs.new_multinomial("Z", 2)
    X1 = vs.new_gaussian("X1")
    X2 = vs.new_gaussian("X2")
    dag = DAG(vs)
    dag.add_parent(X1, Z)
    dag.add_parent(X2, X1)
    bn = BayesianNetwork(dag, {
        "Z": MultinomialCPD(jnp.array([0.5, 0.5])),
        "X1": CLGCPD(jnp.array([0.0, 4.0]), jnp.zeros((2, 0)),
                     jnp.array([1.0, 1.0])),
        "X2": CLGCPD(jnp.asarray(1.0), jnp.asarray([2.0]),
                     jnp.asarray(0.5))})
    ev = np.array([0.0, 2.0, 4.0])
    eng = JunctionTreeEngine(bn)
    eng.set_evidence({"X1": ev})
    eng.run_inference()
    m, v = eng.posterior_mean_var(X2)
    np.testing.assert_allclose(np.asarray(m), 1.0 + 2.0 * ev, atol=1e-6)
    np.testing.assert_allclose(np.asarray(v), 0.5, atol=1e-6)
